//! Per-layer active-expert allocations — the object LExI optimizes.
//!
//! An [`Allocation`] is the vector `k = (k_1, ..., k_L)` of Alg. 2, with
//! the paper's feasibility constraints: a total budget `sum k_j = B` and
//! per-layer bounds `k_min <= k_j <= k_max`.

use crate::util::Pcg32;

/// Per-layer bounds of the Alg. 2 search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bounds {
    pub k_min: u32,
    pub k_max: u32,
}

impl Bounds {
    pub fn new(k_min: u32, k_max: u32) -> Self {
        assert!(k_min >= 1 && k_min <= k_max);
        Bounds { k_min, k_max }
    }

    /// The paper's search space: every integer 1..=k_base.
    pub fn paper(k_base: u32) -> Self {
        Bounds::new(1, k_base)
    }
}

/// A per-layer top-k vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Allocation {
    pub k: Vec<u32>,
}

impl Allocation {
    pub fn new(k: Vec<u32>) -> Self {
        Allocation { k }
    }

    /// Uniform baseline: every layer at k_base.
    pub fn uniform(n_layers: usize, k: u32) -> Self {
        Allocation { k: vec![k; n_layers] }
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Total active-expert budget `sum_j k_j`.
    pub fn budget(&self) -> u32 {
        self.k.iter().sum()
    }

    /// Mean active experts per layer (the x-axis of several figures).
    pub fn mean_k(&self) -> f64 {
        self.budget() as f64 / self.k.len() as f64
    }

    pub fn satisfies(&self, bounds: Bounds, budget: u32) -> bool {
        self.budget() == budget
            && self
                .k
                .iter()
                .all(|&k| k >= bounds.k_min && k <= bounds.k_max)
    }

    /// Random feasible allocation: start at k_min everywhere and spread the
    /// remaining budget uniformly at random (Alg. 2 population init).
    pub fn random_feasible(
        n_layers: usize,
        bounds: Bounds,
        budget: u32,
        rng: &mut Pcg32,
    ) -> Option<Self> {
        let lo = bounds.k_min * n_layers as u32;
        let hi = bounds.k_max * n_layers as u32;
        if budget < lo || budget > hi {
            return None;
        }
        let mut k = vec![bounds.k_min; n_layers];
        let mut rest = budget - lo;
        while rest > 0 {
            let j = rng.gen_usize(n_layers);
            if k[j] < bounds.k_max {
                k[j] += 1;
                rest -= 1;
            }
        }
        Some(Allocation { k })
    }

    /// Project onto the feasible set: clamp to bounds, then repair the
    /// budget with +/-1 steps on randomly chosen adjustable layers
    /// (Alg. 2 `Proj`). Idempotent on already-feasible points.
    pub fn project(&mut self, bounds: Bounds, budget: u32, rng: &mut Pcg32) {
        for k in self.k.iter_mut() {
            *k = (*k).clamp(bounds.k_min, bounds.k_max);
        }
        loop {
            let cur = self.budget();
            match cur.cmp(&budget) {
                std::cmp::Ordering::Equal => break,
                std::cmp::Ordering::Less => {
                    let candidates: Vec<usize> = (0..self.k.len())
                        .filter(|&j| self.k[j] < bounds.k_max)
                        .collect();
                    let j = candidates[rng.gen_usize(candidates.len())];
                    self.k[j] += 1;
                }
                std::cmp::Ordering::Greater => {
                    let candidates: Vec<usize> = (0..self.k.len())
                        .filter(|&j| self.k[j] > bounds.k_min)
                        .collect();
                    let j = candidates[rng.gen_usize(candidates.len())];
                    self.k[j] -= 1;
                }
            }
        }
    }

    /// i32 vector for the runtime graphs' `k_vec` input.
    pub fn to_i32(&self) -> Vec<i32> {
        self.k.iter().map(|&k| k as i32).collect()
    }
}

impl std::fmt::Display for Allocation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, k) in self.k.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{k}")?;
        }
        write!(f, "] (B={})", self.budget())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_feasible_satisfies_constraints() {
        let mut rng = Pcg32::seeded(0);
        let b = Bounds::paper(6);
        for budget in [27, 80, 162] {
            let a = Allocation::random_feasible(27, b, budget, &mut rng).unwrap();
            assert!(a.satisfies(b, budget));
        }
        // infeasible budgets
        assert!(Allocation::random_feasible(27, b, 26, &mut rng).is_none());
        assert!(Allocation::random_feasible(27, b, 163, &mut rng).is_none());
    }

    #[test]
    fn project_repairs_budget() {
        let mut rng = Pcg32::seeded(1);
        let b = Bounds::paper(8);
        let mut a = Allocation::new(vec![9, 0, 4, 4]); // out of bounds
        a.project(b, 16, &mut rng);
        assert!(a.satisfies(b, 16));
        // idempotent
        let before = a.clone();
        a.project(b, 16, &mut rng);
        assert_eq!(a, before);
    }

    #[test]
    fn uniform_budget() {
        let a = Allocation::uniform(24, 4);
        assert_eq!(a.budget(), 96);
        assert!((a.mean_k() - 4.0).abs() < 1e-12);
    }
}
