//! Model geometry: FLOP and byte counts per component, parameterized so
//! the same formulas serve the paper-scale perf model and the analogues.

use crate::config::model::ModelSpec;
/// Geometry of one transformer layer at a given scale.
#[derive(Clone, Copy, Debug)]
pub struct LayerGeom {
    pub hidden: usize,
    /// Per-expert FFN intermediate dim (possibly reduced by intra-pruning).
    pub ffn: usize,
    pub n_heads: usize,
    /// Experts present in the layer (possibly reduced by inter-pruning).
    pub n_experts: usize,
}

impl LayerGeom {
    /// FLOPs for the attention block per token at context length `ctx`
    /// (QKVO projections + score/value matmuls; 2 FLOPs per MAC).
    pub fn attn_flops_per_token(&self, ctx: usize) -> f64 {
        let h = self.hidden as f64;
        let proj = 4.0 * 2.0 * h * h;
        let scores = 2.0 * 2.0 * h * ctx as f64;
        proj + scores
    }

    /// FLOPs for ONE expert's SwiGLU FFN per token (3 GEMMs).
    pub fn expert_flops_per_token(&self) -> f64 {
        3.0 * 2.0 * self.hidden as f64 * self.ffn as f64
    }

    /// Router GEMM FLOPs per token.
    pub fn router_flops_per_token(&self) -> f64 {
        2.0 * self.hidden as f64 * self.n_experts as f64
    }

    /// Bytes of one expert's weights (W1, W3, W2) at `dtype_bytes`.
    pub fn expert_weight_bytes(&self, dtype_bytes: usize) -> f64 {
        (3 * self.hidden * self.ffn * dtype_bytes) as f64
    }

    /// Bytes of the attention weights at `dtype_bytes`.
    pub fn attn_weight_bytes(&self, dtype_bytes: usize) -> f64 {
        (4 * self.hidden * self.hidden * dtype_bytes) as f64
    }
}

/// Whole-model geometry (uniform layers, per Table 1).
#[derive(Clone, Debug)]
pub struct ModelGeom {
    pub n_layers: usize,
    pub layer: LayerGeom,
    pub vocab: usize,
    pub top_k: usize,
}

impl ModelGeom {
    /// Paper-scale geometry of a registry model.
    pub fn paper_scale(spec: &ModelSpec) -> Self {
        ModelGeom {
            n_layers: spec.n_layers,
            layer: LayerGeom {
                hidden: spec.paper.hidden,
                ffn: spec.paper.ffn,
                n_heads: spec.paper.n_heads,
                n_experts: spec.n_experts,
            },
            vocab: spec.paper.vocab,
            top_k: spec.top_k,
        }
    }

    /// Model FLOPs per token with `k_j` active experts in layer j.
    pub fn flops_per_token(&self, k_per_layer: &[u32], ctx: usize) -> f64 {
        assert_eq!(k_per_layer.len(), self.n_layers);
        let l = &self.layer;
        let mut total = 0.0;
        for &k in k_per_layer {
            total += l.attn_flops_per_token(ctx)
                + l.router_flops_per_token()
                + k as f64 * l.expert_flops_per_token();
        }
        total + 2.0 * self.layer.hidden as f64 * self.vocab as f64
    }

    /// Total expert parameters (the "up to 96% of the model" the paper
    /// cites for Mixtral).
    pub fn expert_param_count(&self) -> f64 {
        (self.n_layers * self.layer.n_experts * 3 * self.layer.hidden * self.layer.ffn)
            as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::spec;

    #[test]
    fn mixtral_experts_dominate_params() {
        let g = ModelGeom::paper_scale(&spec("mixtral-8x7b").unwrap());
        let experts = g.expert_param_count();
        let total = 46.7e9;
        assert!(experts / total > 0.9, "expert share {}", experts / total);
    }

    #[test]
    fn flops_monotone_in_k() {
        let g = ModelGeom::paper_scale(&spec("qwen1.5-moe-a2.7b").unwrap());
        let base = g.flops_per_token(&vec![4; 24], 1024);
        let less = g.flops_per_token(&vec![2; 24], 1024);
        assert!(less < base);
        // halving k roughly halves the expert term
        let l = g.layer;
        let expert_term = 24.0 * 2.0 * l.expert_flops_per_token();
        assert!((base - less - expert_term).abs() / base < 1e-9);
    }
}
