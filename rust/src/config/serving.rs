//! Serving-engine configuration (vLLM-lite; defaults mirror the paper's
//! batch-16 H100 setup scaled to the tiny analogues).

#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Static executable batch (slots per forward).
    pub batch: usize,
    /// KV-cache capacity per slot (tokens).
    pub max_seq: usize,
    /// Static prefill graph length.
    pub prefill_len: usize,
    /// KV block size for the block-granular cache accounting.
    pub kv_block: usize,
    /// Total KV blocks available (admission control / preemption).
    pub kv_blocks_total: usize,
    /// Max requests admitted to the waiting queue before rejection.
    pub queue_cap: usize,
    /// Max new tokens per request unless the request says otherwise.
    pub max_new_tokens: usize,
    /// Scheduler: max decode steps between prefill opportunities.
    pub decode_burst: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            batch: 8,
            max_seq: 128,
            prefill_len: 96,
            kv_block: 16,
            kv_blocks_total: 64, // 8 slots * 128 tokens / 16
            queue_cap: 256,
            max_new_tokens: 16,
            decode_burst: 8,
        }
    }
}

impl ServingConfig {
    pub fn blocks_per_seq(&self) -> usize {
        self.max_seq.div_ceil(self.kv_block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_blocks_cover_all_slots() {
        let c = ServingConfig::default();
        assert!(c.kv_blocks_total >= c.batch * c.blocks_per_seq());
    }
}
