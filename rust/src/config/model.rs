//! The paper's Table-1 model registry.
//!
//! Each entry carries (a) the *structural* quantities LExI operates on —
//! layer count, expert count, baseline top-k — shared bit-for-bit with the
//! tiny analogues trained at build time (python/compile/configs.py), and
//! (b) the *paper-scale* dims used by the H100 performance model
//! ([`crate::perfmodel`]) to reproduce the throughput axes of Figs. 2–8.

/// Paper-scale dimensions of the real checkpoint (for the perf model only;
/// the executables in `artifacts/` are the tiny analogues).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PaperScale {
    /// Hidden (model) dimension.
    pub hidden: usize,
    /// Per-expert FFN intermediate dimension.
    pub ffn: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Total parameters, billions (Table 1 "#P (B)").
    pub params_b: f64,
    /// GPUs used in the paper's deployment (4 for most LLMs, 2 for the
    /// DeepSeek models).
    pub n_gpus: usize,
    /// Vocabulary size of the real tokenizer (embedding traffic).
    pub vocab: usize,
}

/// One Table-1 model: structure + paper-scale dims.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Human-readable name as printed in the paper.
    pub paper_name: &'static str,
    pub n_layers: usize,
    pub n_experts: usize,
    /// Baseline pretrained top-k (k_base); the LExI search space is
    /// {1, ..., k_base} per layer.
    pub top_k: usize,
    pub paper: PaperScale,
    pub is_vlm: bool,
}

impl ModelSpec {
    /// Total active-expert budget of the unmodified model: L * k_base.
    pub fn baseline_budget(&self) -> usize {
        self.n_layers * self.top_k
    }

    /// LExI budget sweep used in the figures: fractions of the baseline.
    pub fn budget_sweep(&self) -> Vec<usize> {
        let base = self.baseline_budget();
        let mut out: Vec<usize> = [0.5, 0.65, 0.8]
            .iter()
            .map(|f| ((base as f64 * f).round() as usize).max(self.n_layers))
            .collect();
        out.dedup();
        out
    }
}

pub const MODEL_NAMES: [&str; 6] = [
    "olmoe-1b-7b",
    "qwen1.5-moe-a2.7b",
    "deepseek-v2-lite",
    "minicpm-moe-8x2b",
    "mixtral-8x7b",
    "deepseek-vl2-tiny",
];

/// The five LLMs of Figs. 4-7 (the VLM is evaluated in Fig. 8).
pub const LLM_NAMES: [&str; 5] = [
    "olmoe-1b-7b",
    "qwen1.5-moe-a2.7b",
    "deepseek-v2-lite",
    "minicpm-moe-8x2b",
    "mixtral-8x7b",
];

/// Full registry (paper Table 1).
pub fn registry() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "deepseek-vl2-tiny",
            paper_name: "DeepSeek VL2-Tiny",
            n_layers: 12,
            n_experts: 64,
            top_k: 6,
            paper: PaperScale {
                hidden: 1280,
                ffn: 896,
                n_heads: 10,
                params_b: 3.0,
                n_gpus: 2,
                vocab: 102_400,
            },
            is_vlm: true,
        },
        ModelSpec {
            name: "olmoe-1b-7b",
            paper_name: "OLMoE-1B-7B-0125-Instruct",
            n_layers: 16,
            n_experts: 64,
            top_k: 8,
            paper: PaperScale {
                hidden: 2048,
                ffn: 1024,
                n_heads: 16,
                params_b: 6.92,
                n_gpus: 4,
                vocab: 50_304,
            },
            is_vlm: false,
        },
        ModelSpec {
            name: "qwen1.5-moe-a2.7b",
            paper_name: "Qwen1.5-MoE-A2.7B-Chat",
            n_layers: 24,
            n_experts: 60,
            top_k: 4,
            paper: PaperScale {
                hidden: 2048,
                ffn: 1408,
                n_heads: 16,
                params_b: 14.3,
                n_gpus: 4,
                vocab: 151_936,
            },
            is_vlm: false,
        },
        ModelSpec {
            name: "deepseek-v2-lite",
            paper_name: "DeepSeek-V2-Lite-Chat",
            n_layers: 27,
            n_experts: 64,
            top_k: 6,
            paper: PaperScale {
                hidden: 2048,
                ffn: 1408,
                n_heads: 16,
                params_b: 15.7,
                n_gpus: 2,
                vocab: 102_400,
            },
            is_vlm: false,
        },
        ModelSpec {
            name: "minicpm-moe-8x2b",
            paper_name: "MiniCPM-MoE-8x2B",
            n_layers: 40,
            n_experts: 8,
            top_k: 2,
            paper: PaperScale {
                hidden: 2304,
                ffn: 5760,
                n_heads: 36,
                params_b: 17.0,
                n_gpus: 4,
                vocab: 122_753,
            },
            is_vlm: false,
        },
        ModelSpec {
            name: "mixtral-8x7b",
            paper_name: "Mixtral-8x7B-Instruct-v0.1",
            n_layers: 32,
            n_experts: 8,
            top_k: 2,
            paper: PaperScale {
                hidden: 4096,
                ffn: 14336,
                n_heads: 32,
                params_b: 46.7,
                n_gpus: 4,
                vocab: 32_000,
            },
            is_vlm: false,
        },
    ]
}

/// Look up one model by `name` key (shared with the Python configs).
pub fn spec(name: &str) -> anyhow::Result<ModelSpec> {
    registry()
        .into_iter()
        .find(|m| m.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table1_structure() {
        let t1: &[(&str, usize, usize, usize, f64)] = &[
            ("deepseek-vl2-tiny", 12, 64, 6, 3.0),
            ("olmoe-1b-7b", 16, 64, 8, 6.92),
            ("qwen1.5-moe-a2.7b", 24, 60, 4, 14.3),
            ("deepseek-v2-lite", 27, 64, 6, 15.7),
            ("minicpm-moe-8x2b", 40, 8, 2, 17.0),
            ("mixtral-8x7b", 32, 8, 2, 46.7),
        ];
        for (name, l, e, k, p) in t1 {
            let m = spec(name).unwrap();
            assert_eq!(m.n_layers, *l);
            assert_eq!(m.n_experts, *e);
            assert_eq!(m.top_k, *k);
            assert!((m.paper.params_b - p).abs() < 1e-9);
        }
    }

    #[test]
    fn budgets_are_feasible() {
        for m in registry() {
            for b in m.budget_sweep() {
                assert!(b >= m.n_layers, "budget below k=1 per layer");
                assert!(b <= m.baseline_budget());
            }
        }
    }

    #[test]
    fn unknown_model_errors() {
        assert!(spec("gpt-5").is_err());
    }
}
