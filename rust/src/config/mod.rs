//! Configuration: model registry (Table 1), serving engine, the
//! multi-replica front-end, experiments.

pub mod experiment;
pub mod model;
pub mod server;
pub mod serving;

pub use experiment::ExperimentConfig;
pub use model::{ModelSpec, PaperScale};
pub use server::{PolicyKind, ScenarioKind, ServerConfig};
pub use serving::ServingConfig;
