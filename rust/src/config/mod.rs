//! Configuration: model registry (Table 1), serving engine, experiments.

pub mod experiment;
pub mod model;
pub mod serving;

pub use experiment::ExperimentConfig;
pub use model::{ModelSpec, PaperScale};
pub use serving::ServingConfig;
