//! Multi-replica serving front-end configuration (`lexi bench-serve`).
//!
//! Declarative knobs only — the machinery lives in [`crate::server`].
//! Rates are expressed *relative to estimated cluster capacity* so the
//! same scenario stresses any model the perf model can describe.

use anyhow::{bail, Result};

/// Replica-routing policy of the cluster front door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Cycle through replicas regardless of load.
    RoundRobin,
    /// Join the shortest queue (token-weighted backlog).
    Jsq,
    /// Power-of-two-choices: sample two replicas, pick the lighter.
    PowerOfTwo,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "rr" | "round-robin" => PolicyKind::RoundRobin,
            "jsq" => PolicyKind::Jsq,
            "p2c" | "power-of-two" => PolicyKind::PowerOfTwo,
            other => bail!("unknown routing policy '{other}' (rr | jsq | p2c)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::RoundRobin => "rr",
            PolicyKind::Jsq => "jsq",
            PolicyKind::PowerOfTwo => "p2c",
        }
    }
}

/// Arrival-trace scenario family (shapes live in `server::workload`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Stationary Poisson arrivals at ~70% of capacity.
    Poisson,
    /// Two-state MMPP: long calm phases, short 1.8x-capacity bursts.
    Bursty,
    /// Sinusoidal rate ramp crossing capacity at the peak.
    Diurnal,
    /// Fixed-concurrency closed loop with think times.
    ClosedLoop,
}

impl ScenarioKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "poisson" => ScenarioKind::Poisson,
            "bursty" => ScenarioKind::Bursty,
            "diurnal" => ScenarioKind::Diurnal,
            "closed-loop" | "closedloop" => ScenarioKind::ClosedLoop,
            other => bail!(
                "unknown scenario '{other}' (poisson | bursty | diurnal | closed-loop)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            ScenarioKind::Poisson => "poisson",
            ScenarioKind::Bursty => "bursty",
            ScenarioKind::Diurnal => "diurnal",
            ScenarioKind::ClosedLoop => "closed-loop",
        }
    }

    pub fn all() -> [ScenarioKind; 4] {
        [
            ScenarioKind::Poisson,
            ScenarioKind::Bursty,
            ScenarioKind::Diurnal,
            ScenarioKind::ClosedLoop,
        ]
    }
}

/// Front-end configuration: cluster shape, routing, workload, ladder.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Engine replicas behind the front door.
    pub replicas: usize,
    /// Decode slots per replica (continuous-batching batch size).
    pub slots_per_replica: usize,
    /// Global admission cap on outstanding (queued + running) requests.
    pub queue_cap: usize,
    pub policy: PolicyKind,
    pub scenario: ScenarioKind,
    /// Requests per trace.
    pub n_requests: usize,
    pub seed: u64,
    /// LExI quality-ladder budgets as fractions of L * k_base, one rung
    /// per entry (descending); the baseline (1.0) is always rung 0.
    pub ladder_fracs: Vec<f64>,
    /// Queue depth (requests) above which a replica steps DOWN a rung.
    pub degrade_above: usize,
    /// Queue depth below which a replica climbs back toward rung 0.
    pub upgrade_below: usize,
    /// Minimum virtual time between rung switches (hysteresis).
    pub min_dwell_s: f64,
    /// One-off virtual-time cost of swapping `k_vec` on a replica.
    pub reconfig_penalty_s: f64,
    /// Reference prompt/output lengths for service-model calibration.
    pub service_in_len: usize,
    pub service_out_len: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            replicas: 4,
            slots_per_replica: 16,
            queue_cap: 512,
            policy: PolicyKind::Jsq,
            scenario: ScenarioKind::Bursty,
            n_requests: 512,
            seed: 0,
            ladder_fracs: vec![0.8, 0.65, 0.5],
            degrade_above: 24,
            upgrade_below: 4,
            min_dwell_s: 0.5,
            reconfig_penalty_s: 0.002,
            service_in_len: 512,
            service_out_len: 64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for p in [PolicyKind::RoundRobin, PolicyKind::Jsq, PolicyKind::PowerOfTwo] {
            assert_eq!(PolicyKind::parse(p.label()).unwrap(), p);
        }
        for s in ScenarioKind::all() {
            assert_eq!(ScenarioKind::parse(s.label()).unwrap(), s);
        }
        assert!(PolicyKind::parse("lifo").is_err());
        assert!(ScenarioKind::parse("flash-crowd").is_err());
    }

    #[test]
    fn defaults_are_sane() {
        let c = ServerConfig::default();
        assert!(c.replicas >= 1 && c.slots_per_replica >= 1);
        assert!(c.upgrade_below < c.degrade_above);
        assert!(c.ladder_fracs.iter().all(|&f| f > 0.0 && f < 1.0));
    }
}
