//! Multi-replica serving front-end configuration (`lexi bench-serve`).
//!
//! Declarative knobs only — the machinery lives in [`crate::server`].
//! Rates are expressed *relative to estimated cluster capacity* so the
//! same scenario stresses any model the perf model can describe.

use std::path::PathBuf;

use anyhow::{bail, Result};

/// Replica-routing policy of the cluster front door. Each kind maps to
/// a [`RoutingPolicy`](crate::server::router::RoutingPolicy) impl.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Cycle through replicas regardless of load.
    RoundRobin,
    /// Join the shortest queue (token-weighted backlog).
    Jsq,
    /// Power-of-two-choices: sample two replicas, pick the lighter.
    PowerOfTwo,
    /// SLO-class-aware joint rung+routing: batch classes are steered to
    /// degraded (deep-rung) replicas, interactive classes keep the
    /// full-quality ones; load breaks ties (JSQ on a uniform cluster).
    ClassAware,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "rr" | "round-robin" => PolicyKind::RoundRobin,
            "jsq" => PolicyKind::Jsq,
            "p2c" | "power-of-two" => PolicyKind::PowerOfTwo,
            "classaware" | "class-aware" => PolicyKind::ClassAware,
            other => bail!("unknown routing policy '{other}' (rr | jsq | p2c | classaware)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::RoundRobin => "rr",
            PolicyKind::Jsq => "jsq",
            PolicyKind::PowerOfTwo => "p2c",
            PolicyKind::ClassAware => "classaware",
        }
    }
}

/// Pressure signal driving the adaptive-ladder controller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PressureMode {
    /// Queue depth against the degrade/upgrade thresholds (the original
    /// rule, bit-identical).
    Queue,
    /// Normalized EDF slack of queued interactive requests: degrade
    /// when deadlines start collapsing, not when mean depth rises.
    Slack,
    /// Predictive slack: EDF slack projected forward by the replica's
    /// step-time EWMA x queue depth, so the controller reacts to where
    /// slack WILL be once the backlog drains, not where it is now.
    SlackEwma,
    /// SLO error-budget burn rate from the health engine
    /// ([`crate::obs::health`]): degrade when the fast-window burn
    /// approaches the critical threshold, recover when the budget stops
    /// burning. Implies `--health`.
    Burn,
}

impl PressureMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "queue" => PressureMode::Queue,
            "slack" => PressureMode::Slack,
            "slack-ewma" | "slackewma" => PressureMode::SlackEwma,
            "burn" => PressureMode::Burn,
            other => bail!("unknown pressure mode '{other}' (queue | slack | slack-ewma | burn)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            PressureMode::Queue => "queue",
            PressureMode::Slack => "slack",
            PressureMode::SlackEwma => "slack-ewma",
            PressureMode::Burn => "burn",
        }
    }
}

/// Which axes span the quality lattice the ladder controller walks
/// (`--ladder-axes`). The first axis is always the per-layer
/// active-expert budget (the paper's Stage-2 k_vec rungs); the second —
/// when enabled — is an intra-expert lever priced independently, so a
/// rung becomes a [`PointId`](crate::server::ladder::PointId) in a 2-D
/// lattice instead of an index into a Vec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LadderAxes {
    /// Active-expert budgets only: the historical 1-D ladder,
    /// bit-identical to every earlier release.
    K,
    /// k_vec budgets x MoE-I2-style intra-expert FFN sparsity
    /// (`--intra-fracs`).
    KIntra,
    /// k_vec budgets x NAEE dynamic-skip aggressiveness
    /// (`--skip-thresholds`); construction fails on non-top-2 models.
    KSkip,
}

impl LadderAxes {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "k" => LadderAxes::K,
            "k-intra" | "kintra" => LadderAxes::KIntra,
            "k-skip" | "kskip" => LadderAxes::KSkip,
            other => bail!("unknown ladder axes '{other}' (k | k-intra | k-skip)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            LadderAxes::K => "k",
            LadderAxes::KIntra => "k-intra",
            LadderAxes::KSkip => "k-skip",
        }
    }
}

/// Validate quality-ladder budget fractions at config-parse time: each
/// must be a finite fraction strictly inside (0, 1) — rung 0 is always
/// the full-budget baseline, so 1.0 would duplicate it, and a NaN here
/// used to reach `QualityLattice::for_model`'s sort and panic mid-build.
pub fn validate_ladder_fracs(fracs: &[f64]) -> Result<()> {
    for &f in fracs {
        if !f.is_finite() || f <= 0.0 || f >= 1.0 {
            bail!(
                "--ladder frac {f} is not a fraction in (0, 1) exclusive \
                 (rung 0 is always the full 1.0 budget)"
            );
        }
    }
    Ok(())
}

/// Validate the second-axis sparsity levels (`--intra-fracs` FFN prune
/// fractions in (0, 1); `--skip-thresholds` gate ratios in (0, 1]).
/// Level 0 of the axis is always dense/off, so 0.0 entries are rejected
/// as duplicates of it.
pub fn validate_axis_levels(levels: &[f64], axes: LadderAxes) -> Result<()> {
    let (name, hi_ok) = match axes {
        LadderAxes::K => return Ok(()),
        LadderAxes::KIntra => ("--intra-fracs", false),
        LadderAxes::KSkip => ("--skip-thresholds", true),
    };
    for &v in levels {
        let in_range = v.is_finite() && v > 0.0 && (v < 1.0 || (hi_ok && v == 1.0));
        if !in_range {
            bail!(
                "{name} entry {v} is out of range (level 0 of the axis is always \
                 dense/off; entries must be finite, > 0 and {})",
                if hi_ok { "<= 1" } else { "< 1" }
            );
        }
    }
    Ok(())
}

/// HBM eviction policy of the expert residency store. The
/// implementations live in [`crate::experts::policy`]
/// (`EvictKind::build`, mirroring `PolicyKind::build`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictKind {
    /// Evict the least-recently demanded expert.
    Lru,
    /// Evict the least-frequently demanded expert.
    Lfu,
    /// Pin each layer's top-`k_vec[j]` experts by routing popularity
    /// (the LExI hot set); LRU over the remaining pool.
    KvecAware,
}

impl EvictKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lru" => EvictKind::Lru,
            "lfu" => EvictKind::Lfu,
            "kvec" | "kvec-aware" | "kvecaware" => EvictKind::KvecAware,
            other => bail!("unknown eviction policy '{other}' (lru | lfu | kvec)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvictKind::Lru => "lru",
            EvictKind::Lfu => "lfu",
            EvictKind::KvecAware => "kvec",
        }
    }

    pub fn all() -> [EvictKind; 3] {
        [EvictKind::Lru, EvictKind::Lfu, EvictKind::KvecAware]
    }
}

/// Arrival-trace scenario family (shapes live in `server::workload`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Stationary Poisson arrivals at ~70% of capacity.
    Poisson,
    /// Two-state MMPP: long calm phases, short 1.8x-capacity bursts.
    Bursty,
    /// Sinusoidal rate ramp crossing capacity at the peak.
    Diurnal,
    /// Fixed-concurrency closed loop with think times.
    ClosedLoop,
    /// Step-function overload: calm, then an instantaneous 3x-capacity
    /// spike, then calm again.
    FlashCrowd,
    /// Replay of a recorded request log (`--trace-file <jsonl>`).
    TraceReplay,
}

impl ScenarioKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "poisson" => ScenarioKind::Poisson,
            "bursty" => ScenarioKind::Bursty,
            "diurnal" => ScenarioKind::Diurnal,
            "closed-loop" | "closedloop" => ScenarioKind::ClosedLoop,
            "flash-crowd" | "flashcrowd" => ScenarioKind::FlashCrowd,
            "trace-replay" | "replay" => ScenarioKind::TraceReplay,
            other => bail!(
                "unknown scenario '{other}' (poisson | bursty | diurnal | closed-loop | \
                 flash-crowd | trace-replay)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            ScenarioKind::Poisson => "poisson",
            ScenarioKind::Bursty => "bursty",
            ScenarioKind::Diurnal => "diurnal",
            ScenarioKind::ClosedLoop => "closed-loop",
            ScenarioKind::FlashCrowd => "flash-crowd",
            ScenarioKind::TraceReplay => "trace-replay",
        }
    }

    /// The generative scenario catalog (`--scenario all`). Trace replay
    /// is deliberately absent: it needs a `--trace-file`.
    pub fn all() -> [ScenarioKind; 5] {
        [
            ScenarioKind::Poisson,
            ScenarioKind::Bursty,
            ScenarioKind::Diurnal,
            ScenarioKind::ClosedLoop,
            ScenarioKind::FlashCrowd,
        ]
    }
}

/// Replica-backend family behind the cluster front door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Virtual-time replicas calibrated from the analytical perf model
    /// (deterministic, artifact-free).
    Sim,
    /// Real `engine::Engine` replicas: compiled PJRT runtime when
    /// artifacts + real bindings exist, host-synthetic model otherwise.
    Engine,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sim" => BackendKind::Sim,
            "engine" => BackendKind::Engine,
            other => bail!("unknown backend '{other}' (sim | engine)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Engine => "engine",
        }
    }
}

/// Where the Stage-1 sensitivity table for ladder construction comes
/// from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableMode {
    /// Measured table when cached in the artifacts dir, synthetic depth
    /// profile otherwise.
    Auto,
    /// Always the synthetic depth profile.
    Synthetic,
    /// Require the measured table; error when it is missing or does not
    /// match the model.
    Measured,
}

impl TableMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => TableMode::Auto,
            "synthetic" => TableMode::Synthetic,
            "measured" => TableMode::Measured,
            other => bail!("unknown table mode '{other}' (auto | synthetic | measured)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            TableMode::Auto => "auto",
            TableMode::Synthetic => "synthetic",
            TableMode::Measured => "measured",
        }
    }
}

/// Scope of the adaptive-ladder rung controller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LadderScope {
    /// Each replica follows its own queue-depth hysteresis (the PR 1
    /// behavior, bit-for-bit).
    PerReplica,
    /// One controller reads aggregate pressure and staggers switches
    /// across replicas.
    Cluster,
}

impl LadderScope {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "replica" | "per-replica" => LadderScope::PerReplica,
            "cluster" | "global" => LadderScope::Cluster,
            other => bail!("unknown ladder scope '{other}' (replica | cluster)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            LadderScope::PerReplica => "replica",
            LadderScope::Cluster => "cluster",
        }
    }
}

/// Hardware performance tier of a replica in a heterogeneous cluster
/// (`--replica-tiers h100:4,a100:4`). Maps to a
/// [`Hardware`](crate::perfmodel::hardware::Hardware) constant set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TierKind {
    /// The paper's testbed accelerator (the uniform-cluster default).
    H100,
    /// Previous-generation tier: ~1/3 the compute, HBM2e, PCIe Gen4.
    A100,
}

impl TierKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "h100" => TierKind::H100,
            "a100" => TierKind::A100,
            other => bail!("unknown hardware tier '{other}' (h100 | a100)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            TierKind::H100 => "h100",
            TierKind::A100 => "a100",
        }
    }

    /// Parse a `tier:count,tier:count` spec into an ordered tier list
    /// (the order assigns replica indices: first spec entry gets the
    /// lowest indices).
    pub fn parse_spec(spec: &str) -> Result<Vec<(TierKind, usize)>> {
        let mut tiers = Vec::new();
        for part in spec.split(',') {
            let (tier, count) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("tier spec '{part}' is not tier:count"))?;
            let n: usize = count
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("tier count '{count}' is not an integer"))?;
            if n == 0 {
                bail!("tier '{tier}' has zero replicas");
            }
            tiers.push((TierKind::parse(tier.trim())?, n));
        }
        if tiers.is_empty() {
            bail!("empty replica-tier spec");
        }
        Ok(tiers)
    }
}

/// Parse an autoscaler range `min:max` (both ends inclusive).
pub fn parse_autoscale(spec: &str) -> Result<(usize, usize)> {
    let (lo, hi) = spec
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("autoscale spec '{spec}' is not min:max"))?;
    let min: usize = lo
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("autoscale min '{lo}' is not an integer"))?;
    let max: usize = hi
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("autoscale max '{hi}' is not an integer"))?;
    if min == 0 || min > max {
        bail!("autoscale range {min}:{max} must satisfy 1 <= min <= max");
    }
    Ok((min, max))
}

/// Front-end configuration: cluster shape, routing, workload, ladder.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Engine replicas behind the front door.
    pub replicas: usize,
    /// Decode slots per replica (continuous-batching batch size).
    pub slots_per_replica: usize,
    /// Global admission cap on outstanding (queued + running) requests.
    pub queue_cap: usize,
    pub policy: PolicyKind,
    pub scenario: ScenarioKind,
    /// Which replica implementation the cluster drives.
    pub backend: BackendKind,
    /// Stage-1 table source for ladder construction.
    pub table_mode: TableMode,
    /// Requests per trace.
    pub n_requests: usize,
    pub seed: u64,
    /// LExI quality-ladder budgets as fractions of L * k_base, one rung
    /// per entry (descending); the baseline (1.0) is always rung 0.
    pub ladder_fracs: Vec<f64>,
    /// Axes spanning the quality lattice (`--ladder-axes`). The default
    /// [`LadderAxes::K`] keeps the historical 1-D budget ladder
    /// bit-identical; the other settings add a second sparsity axis.
    pub ladder_axes: LadderAxes,
    /// Intra-expert FFN prune fractions for the second lattice axis
    /// (`--ladder-axes k-intra`), one sparsity level per entry in
    /// ascending aggressiveness; level 0 (dense) is always present.
    pub intra_fracs: Vec<f64>,
    /// Dynamic-skip gate thresholds for the second lattice axis
    /// (`--ladder-axes k-skip`), ascending; level 0 (no skipping) is
    /// always present.
    pub skip_thresholds: Vec<f64>,
    /// Queue depth (requests) above which a replica steps DOWN a rung.
    pub degrade_above: usize,
    /// Queue depth below which a replica climbs back toward rung 0.
    pub upgrade_below: usize,
    /// Minimum event-loop time between rung switches (hysteresis).
    pub min_dwell_s: f64,
    /// Per-replica rule vs. cluster-global co-optimization.
    pub ladder_scope: LadderScope,
    /// Cluster scope only: rung switches allowed per event-loop instant.
    pub max_switches_per_instant: usize,
    /// Ladder pressure signal: queue depth or interactive EDF slack.
    pub pressure: PressureMode,
    /// Slack mode: degrade when the worst queued interactive slack
    /// fraction (slack / TTFT SLO) falls below this.
    pub slack_degrade_frac: f64,
    /// Slack mode: recover when it rises back above this.
    pub slack_upgrade_frac: f64,
    /// Cross-replica steals allowed per dispatch instant (0 = off).
    pub steal_bound: usize,
    /// Minimum event-loop time between steals touching one replica
    /// (thief or victim) — hysteresis so engine-backed replicas don't
    /// thrash work back and forth. 0 = per-instant bound only.
    pub steal_cooldown_s: f64,
    /// Expert-residency HBM budget as a fraction of the model's full
    /// expert footprint (`None` = every expert resident at zero cost,
    /// the historical behavior).
    pub hbm_budget_frac: Option<f64>,
    /// Eviction policy of the residency store (only with a budget).
    pub evict: EvictKind,
    /// Predictive prefetch of next-layer experts (only with a budget).
    pub prefetch: bool,
    /// Request log for `--scenario trace-replay`.
    pub trace_file: Option<PathBuf>,
    /// Calibration artifact (`lexi calibrate` output) whose fitted
    /// service terms replace the analytical sim `ServiceModel`s
    /// (`--calibration <file>`). `None` — the default — keeps every
    /// sim output byte-identical to the uncalibrated releases.
    pub calibration_file: Option<PathBuf>,
    /// One-off event-loop cost of swapping `k_vec` on a replica.
    pub reconfig_penalty_s: f64,
    /// Reference prompt/output lengths for service-model calibration.
    pub service_in_len: usize,
    pub service_out_len: usize,
    /// Record request-span traces and emit the observability artifacts
    /// (Perfetto JSON, critical-path CSV, Prometheus text, JSONL
    /// snapshots) per transform (`--trace`). Off — the default — keeps
    /// every run byte-identical to the untraced build (see
    /// [`crate::obs`]).
    pub trace: bool,
    /// Span-event ring-buffer capacity; oldest events drop (and are
    /// counted) beyond it.
    pub trace_ring_cap: usize,
    /// Virtual-time interval between JSONL metrics snapshots.
    pub metrics_interval_s: f64,
    /// Wall-clock self-profile of the sim's own hot sections
    /// (`--selfprof`), appended to the repo-root `BENCH_selfprof.json`.
    pub selfprof: bool,
    /// Class-aware admission shedding (`--shed`): drop batch-priority
    /// work under pressure before the hard cap rejects interactive
    /// work. Off — the default — keeps admission bit-identical to the
    /// pass-through cap.
    pub shed: bool,
    /// Telemetry-driven replica autoscaling range `(min, max)`
    /// (`--autoscale min:max`). `None` — the default — keeps the
    /// replica set fixed at `replicas`.
    pub autoscale: Option<(usize, usize)>,
    /// Heterogeneous hardware tiers, in replica-index order
    /// (`--replica-tiers h100:4,a100:4`). `None` — the default — is a
    /// uniform H100 cluster, bit-identical to earlier releases.
    pub replica_tiers: Option<Vec<(TierKind, usize)>>,
    /// Replica-stepping shard count (`--shards`). Replica advancement
    /// between routing instants is chunked into this many groups and
    /// the per-shard results merged in replica-index order, so any
    /// value produces a byte-identical schedule; 1 — the default — is
    /// the plain serial loop.
    pub shards: usize,
    /// Streaming SLO health engine (`--health`): windowed burn-rate
    /// monitoring, anomaly detection, and critical-event debug bundles
    /// (see [`crate::obs::health`]). Off — the default — keeps every
    /// run byte-identical; on, it *observes only* unless the pressure
    /// mode is [`PressureMode::Burn`].
    pub health: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            replicas: 4,
            slots_per_replica: 16,
            queue_cap: 512,
            policy: PolicyKind::Jsq,
            scenario: ScenarioKind::Bursty,
            backend: BackendKind::Sim,
            table_mode: TableMode::Auto,
            n_requests: 512,
            seed: 0,
            ladder_fracs: vec![0.8, 0.65, 0.5],
            ladder_axes: LadderAxes::K,
            intra_fracs: vec![0.25, 0.5],
            skip_thresholds: vec![0.3, 0.6],
            degrade_above: 24,
            upgrade_below: 4,
            min_dwell_s: 0.5,
            ladder_scope: LadderScope::PerReplica,
            max_switches_per_instant: 1,
            pressure: PressureMode::Queue,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            steal_bound: 0,
            steal_cooldown_s: 0.0,
            hbm_budget_frac: None,
            evict: EvictKind::KvecAware,
            prefetch: true,
            trace_file: None,
            calibration_file: None,
            reconfig_penalty_s: 0.002,
            service_in_len: 512,
            service_out_len: 64,
            trace: false,
            trace_ring_cap: 1 << 20,
            metrics_interval_s: 1.0,
            selfprof: false,
            shed: false,
            autoscale: None,
            replica_tiers: None,
            shards: 1,
            health: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for p in [PolicyKind::RoundRobin, PolicyKind::Jsq, PolicyKind::PowerOfTwo] {
            assert_eq!(PolicyKind::parse(p.label()).unwrap(), p);
        }
        for s in ScenarioKind::all() {
            assert_eq!(ScenarioKind::parse(s.label()).unwrap(), s);
        }
        for b in [BackendKind::Sim, BackendKind::Engine] {
            assert_eq!(BackendKind::parse(b.label()).unwrap(), b);
        }
        for t in [TableMode::Auto, TableMode::Synthetic, TableMode::Measured] {
            assert_eq!(TableMode::parse(t.label()).unwrap(), t);
        }
        for l in [LadderScope::PerReplica, LadderScope::Cluster] {
            assert_eq!(LadderScope::parse(l.label()).unwrap(), l);
        }
        for p in [
            PressureMode::Queue,
            PressureMode::Slack,
            PressureMode::SlackEwma,
            PressureMode::Burn,
        ] {
            assert_eq!(PressureMode::parse(p.label()).unwrap(), p);
        }
        for e in EvictKind::all() {
            assert_eq!(EvictKind::parse(e.label()).unwrap(), e);
        }
        for a in [LadderAxes::K, LadderAxes::KIntra, LadderAxes::KSkip] {
            assert_eq!(LadderAxes::parse(a.label()).unwrap(), a);
        }
        assert_eq!(LadderAxes::parse("kintra").unwrap(), LadderAxes::KIntra);
        assert!(LadderAxes::parse("k-cubed").is_err());
        assert_eq!(EvictKind::parse("kvec-aware").unwrap(), EvictKind::KvecAware);
        assert!(EvictKind::parse("fifo").is_err());
        assert_eq!(PolicyKind::parse("classaware").unwrap(), PolicyKind::ClassAware);
        assert_eq!(
            ScenarioKind::parse("trace-replay").unwrap(),
            ScenarioKind::TraceReplay
        );
        assert!(PolicyKind::parse("lifo").is_err());
        assert!(ScenarioKind::parse("tsunami").is_err());
        assert!(BackendKind::parse("quantum").is_err());
        assert!(TableMode::parse("guess").is_err());
        assert!(LadderScope::parse("galaxy").is_err());
        assert!(PressureMode::parse("vibes").is_err());
        for t in [TierKind::H100, TierKind::A100] {
            assert_eq!(TierKind::parse(t.label()).unwrap(), t);
        }
        assert!(TierKind::parse("tpu").is_err());
    }

    #[test]
    fn tier_spec_parses_ordered_counts() {
        let tiers = TierKind::parse_spec("h100:2, a100:3").unwrap();
        assert_eq!(tiers, vec![(TierKind::H100, 2), (TierKind::A100, 3)]);
        assert!(TierKind::parse_spec("h100").is_err());
        assert!(TierKind::parse_spec("h100:0").is_err());
        assert!(TierKind::parse_spec("h100:two").is_err());
        assert!(TierKind::parse_spec("tpu:4").is_err());
    }

    #[test]
    fn autoscale_spec_parses_range() {
        assert_eq!(parse_autoscale("2:8").unwrap(), (2, 8));
        assert_eq!(parse_autoscale("4:4").unwrap(), (4, 4));
        assert!(parse_autoscale("8:2").is_err());
        assert!(parse_autoscale("0:4").is_err());
        assert!(parse_autoscale("4").is_err());
        assert!(parse_autoscale("a:b").is_err());
    }

    #[test]
    fn ladder_frac_validation_rejects_nan_and_out_of_range() {
        // satellite of the lattice redesign: a bad frac must be a config
        // error with a message, never a partial_cmp().unwrap() panic
        // inside ladder construction
        assert!(validate_ladder_fracs(&[0.8, 0.65, 0.5]).is_ok());
        assert!(validate_ladder_fracs(&[]).is_ok());
        for bad in [f64::NAN, f64::INFINITY, 0.0, -0.5, 1.0, 1.5] {
            assert!(
                validate_ladder_fracs(&[0.8, bad]).is_err(),
                "frac {bad} accepted"
            );
        }
    }

    #[test]
    fn axis_level_validation_matches_axis_semantics() {
        assert!(validate_axis_levels(&[0.25, 0.5], LadderAxes::KIntra).is_ok());
        assert!(validate_axis_levels(&[0.3, 1.0], LadderAxes::KSkip).is_ok());
        // intra frac 1.0 would zero the whole FFN
        assert!(validate_axis_levels(&[1.0], LadderAxes::KIntra).is_err());
        for bad in [f64::NAN, 0.0, -0.1, 2.0] {
            assert!(validate_axis_levels(&[bad], LadderAxes::KIntra).is_err());
            assert!(validate_axis_levels(&[bad], LadderAxes::KSkip).is_err());
        }
        // the k axis carries no levels to validate
        assert!(validate_axis_levels(&[f64::NAN], LadderAxes::K).is_ok());
    }

    #[test]
    fn defaults_are_sane() {
        let c = ServerConfig::default();
        assert!(c.replicas >= 1 && c.slots_per_replica >= 1);
        assert!(c.upgrade_below < c.degrade_above);
        assert!(c.ladder_fracs.iter().all(|&f| f > 0.0 && f < 1.0));
        validate_ladder_fracs(&c.ladder_fracs).unwrap();
        assert_eq!(c.ladder_axes, LadderAxes::K, "2-D lattice must default OFF");
        validate_axis_levels(&c.intra_fracs, LadderAxes::KIntra).unwrap();
        validate_axis_levels(&c.skip_thresholds, LadderAxes::KSkip).unwrap();
        assert_eq!(c.backend, BackendKind::Sim);
        assert_eq!(c.ladder_scope, LadderScope::PerReplica);
        assert!(c.max_switches_per_instant >= 1);
        // extended control-plane features default OFF: the default
        // feature set must stay bit-identical to earlier releases
        assert_eq!(c.pressure, PressureMode::Queue);
        assert_eq!(c.steal_bound, 0);
        assert_eq!(c.steal_cooldown_s, 0.0);
        assert!(c.hbm_budget_frac.is_none(), "residency must default OFF");
        assert!(c.trace_file.is_none());
        assert!(c.calibration_file.is_none(), "calibration must default OFF");
        assert!(0.0 < c.slack_degrade_frac && c.slack_degrade_frac < c.slack_upgrade_frac);
        assert!(!c.trace, "tracing must default OFF");
        assert!(!c.selfprof, "self-profiling must default OFF");
        assert!(c.trace_ring_cap > 0);
        assert!(c.metrics_interval_s > 0.0);
        assert!(!c.shed, "shedding must default OFF");
        assert!(c.autoscale.is_none(), "autoscaling must default OFF");
        assert!(c.replica_tiers.is_none(), "hetero tiers must default OFF");
        assert_eq!(c.shards, 1, "sharded stepping must default to serial");
        assert!(!c.health, "health engine must default OFF");
    }
}
