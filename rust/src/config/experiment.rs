//! Experiment-level knobs shared by the figure harness and the CLI.
//!
//! The defaults are sized for a single CPU core (see DESIGN.md §3); the
//! paper's original counts are noted inline. `ExperimentConfig::fast()`
//! shrinks everything further for tests/CI.

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Stage-1 Monte-Carlo iterations per (layer, k). Paper: "millions of
    /// random input samples"; the estimator's std-err scales 1/sqrt(N·T)
    /// and with 128 tokens/iter the heatmap stabilizes by ~16 iters.
    pub sensitivity_iters: usize,
    /// Tokens per Stage-1 probe batch (fixed by the moe_layer graph).
    pub profile_tokens: usize,
    /// Stage-2 GA population size.
    pub ga_population: usize,
    /// Stage-2 GA generations.
    pub ga_generations: usize,
    /// Stage-2 GA mutation rate (per-layer probability of a +/-1 swap).
    pub ga_mutation: f64,
    /// Pruning ratios evaluated for the baselines (paper: 12.5/25/50 %).
    pub prune_fracs: Vec<f64>,
    /// Monte-Carlo routing trials in the load-balance model.
    pub routing_trials: usize,
    /// Batch size of the paper's throughput runs.
    pub paper_batch: usize,
    /// Input/output sequence lengths of the paper's throughput runs.
    pub paper_in_len: usize,
    pub paper_out_len: usize,
    /// RNG seed for every stochastic component.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            sensitivity_iters: 16,
            profile_tokens: 128,
            ga_population: 64,
            ga_generations: 400,
            ga_mutation: 0.3,
            prune_fracs: vec![0.125, 0.25, 0.5],
            routing_trials: 64,
            paper_batch: 16,
            paper_in_len: 1024,
            paper_out_len: 512,
            seed: 0,
        }
    }
}

impl ExperimentConfig {
    /// Shrunk version for unit/integration tests.
    pub fn fast() -> Self {
        ExperimentConfig {
            sensitivity_iters: 2,
            ga_population: 16,
            ga_generations: 40,
            routing_trials: 8,
            ..Default::default()
        }
    }
}
