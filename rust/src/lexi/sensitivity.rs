//! Stage 1 (Alg. 1): per-layer top-k perturbation profiling.
//!
//! For each layer j and candidate k, feed `N_iter` batches of
//! `X ~ N(0,1)^{T x H}` through the layer's compiled MoE graph at the
//! baseline top-k and at k, and average the Frobenius deviation
//! `Δ = ||Y_k - Y_base||_F`. Entirely data-free: only the model weights
//! (inside the executable inputs) and synthetic Gaussians are used.

use anyhow::Result;

use crate::config::experiment::ExperimentConfig;
use crate::runtime::ModelRuntime;
use crate::util::{stats::frobenius_diff, Pcg32};

use super::proxy::SensitivityTable;

/// Progress callback: (layer, n_layers).
pub type Progress<'a> = Option<&'a dyn Fn(usize, usize)>;

/// Run Alg. 1 on a loaded model. `cfg.sensitivity_iters` Monte-Carlo
/// iterations per layer; every iteration evaluates all candidate k on the
/// SAME input (paired estimator — lower variance than independent draws).
pub fn profile_model(
    model: &ModelRuntime,
    cfg: &ExperimentConfig,
    progress: Progress,
) -> Result<SensitivityTable> {
    let e = &model.entry;
    let k_base = e.top_k as u32;
    let t = e.profile_tokens;
    let h = e.hidden;
    let mut loss = vec![vec![0.0f64; k_base as usize]; e.n_layers];

    let mut x = vec![0.0f32; t * h];
    for layer in 0..e.n_layers {
        if let Some(p) = progress {
            p(layer, e.n_layers);
        }
        // Deterministic per-layer stream so layers are comparable and the
        // table is reproducible regardless of evaluation order.
        let mut rng = Pcg32::new(cfg.seed, 0xA16_0001 + layer as u64);
        for _ in 0..cfg.sensitivity_iters {
            rng.fill_normal_f32(&mut x);
            let y_base = model.moe_layer(layer, &x, k_base as i32)?;
            for k in 1..=k_base {
                if k == k_base {
                    continue; // Δ is 0 by construction
                }
                let y_k = model.moe_layer(layer, &x, k as i32)?;
                loss[layer][(k - 1) as usize] += frobenius_diff(&y_k, &y_base);
            }
        }
        for v in loss[layer].iter_mut() {
            *v /= cfg.sensitivity_iters as f64;
        }
    }

    Ok(SensitivityTable {
        model: e.name.clone(),
        k_base,
        loss,
        iters: cfg.sensitivity_iters,
    })
}

/// Sanity checks on a measured table (used by integration tests and the
/// CLI's `--verify` flag): Δ at k_base is 0 and Δ is non-increasing in k
/// (selection sets are nested — see kernels/topk_gate.py).
pub fn verify_table(table: &SensitivityTable) -> Result<()> {
    for (j, row) in table.loss.iter().enumerate() {
        let last = *row.last().unwrap();
        anyhow::ensure!(
            last.abs() < 1e-3,
            "layer {j}: Δ at k_base = {last}, expected ~0"
        );
        for (k, w) in row.windows(2).enumerate() {
            anyhow::ensure!(
                w[1] <= w[0] * 1.05 + 1e-6,
                "layer {j}: Δ not monotone at k={}: {} -> {}",
                k + 1,
                w[0],
                w[1]
            );
        }
    }
    Ok(())
}
