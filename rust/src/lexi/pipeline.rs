//! The end-to-end LExI pipeline: Stage 1 (profile, cached) -> Stage 2
//! (evolutionary search) -> per-layer allocation.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::experiment::ExperimentConfig;
use crate::moe::allocation::{Allocation, Bounds};
use crate::runtime::ModelRuntime;

use super::evolution::{evolve, EvolutionParams, EvolutionResult};
use super::proxy::SensitivityTable;
use super::sensitivity::{profile_model, verify_table};

/// Stage-1 result cache location for a model.
pub fn table_path(artifacts: &std::path::Path, model: &str) -> PathBuf {
    artifacts.join(model).join("sensitivity.json")
}

/// Run (or load cached) Stage 1 for a loaded model.
pub fn stage1(
    model: &ModelRuntime,
    cfg: &ExperimentConfig,
    cache: Option<&std::path::Path>,
    force: bool,
) -> Result<SensitivityTable> {
    if let Some(path) = cache {
        if !force && path.exists() {
            let t = SensitivityTable::load_json(path)?;
            if t.iters >= cfg.sensitivity_iters && t.n_layers() == model.entry.n_layers {
                return Ok(t);
            }
        }
    }
    let t = profile_model(model, cfg, None)?;
    verify_table(&t)?;
    if let Some(path) = cache {
        t.save_json(path)?;
    }
    Ok(t)
}

/// Run Stage 2 for one budget on a Stage-1 table.
pub fn stage2(
    table: &SensitivityTable,
    budget: u32,
    cfg: &ExperimentConfig,
) -> Result<EvolutionResult> {
    let bounds = Bounds::paper(table.k_base);
    let params = EvolutionParams {
        population: cfg.ga_population,
        generations: cfg.ga_generations,
        mutation_rate: cfg.ga_mutation,
        tournament: 4,
        seed: cfg.seed,
    };
    evolve(table, budget, bounds, &params)
        .ok_or_else(|| anyhow::anyhow!("budget {budget} infeasible for {}", table.model))
}

/// Full pipeline for a budget sweep. Returns (budget, allocation) pairs.
pub fn optimize(
    model: &ModelRuntime,
    budgets: &[u32],
    cfg: &ExperimentConfig,
    cache: Option<&std::path::Path>,
) -> Result<Vec<(u32, Allocation)>> {
    let table = stage1(model, cfg, cache, false)?;
    budgets
        .iter()
        .map(|&b| Ok((b, stage2(&table, b, cfg)?.best)))
        .collect()
}
