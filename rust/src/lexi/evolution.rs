//! Stage 2 (Alg. 2): evolutionary top-k allocation optimization.
//!
//! GA over feasible allocations: tournament selection, uniform crossover
//! (per-layer Bernoulli(0.5) parent choice), budget-preserving mutation
//! (paired +1/-1 so `sum_j Δ_j = 0`), and projection back to the feasible
//! set. The fitness is the Stage-1 proxy `phi(k) = sum_j D_j(k_j)` — no
//! model execution inside the loop, which is what makes the search
//! "computationally efficient ... without needing to load the actual
//! model" (paper §4).

use crate::moe::allocation::{Allocation, Bounds};
use crate::util::Pcg32;

use super::proxy::SensitivityTable;

#[derive(Clone, Copy, Debug)]
pub struct EvolutionParams {
    pub population: usize,
    pub generations: usize,
    /// Per-layer probability of receiving a paired +/-1 mutation.
    pub mutation_rate: f64,
    /// Tournament size for parent selection.
    pub tournament: usize,
    pub seed: u64,
}

impl Default for EvolutionParams {
    fn default() -> Self {
        EvolutionParams {
            population: 64,
            generations: 400,
            mutation_rate: 0.3,
            tournament: 4,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EvolutionResult {
    pub best: Allocation,
    pub best_fitness: f64,
    /// Best fitness per generation (convergence curve).
    pub history: Vec<f64>,
    pub evaluations: usize,
}

/// Run Alg. 2 for one budget. Returns None iff the budget is infeasible
/// under the bounds.
pub fn evolve(
    table: &SensitivityTable,
    budget: u32,
    bounds: Bounds,
    params: &EvolutionParams,
) -> Option<EvolutionResult> {
    let n_layers = table.n_layers();
    let mut rng = Pcg32::seeded(params.seed ^ budget as u64);

    // Population init: random feasible allocations.
    let mut pop: Vec<Allocation> = (0..params.population)
        .map(|_| Allocation::random_feasible(n_layers, bounds, budget, &mut rng))
        .collect::<Option<Vec<_>>>()?;
    let mut fit: Vec<f64> = pop.iter().map(|a| table.fitness(&a.k)).collect();
    let mut evaluations = pop.len();

    let mut history = Vec::with_capacity(params.generations);
    for _gen in 0..params.generations {
        // Tournament selection of two parents.
        let pick = |rng: &mut Pcg32, fit: &[f64]| -> usize {
            let mut best = rng.gen_usize(fit.len());
            for _ in 1..params.tournament {
                let c = rng.gen_usize(fit.len());
                if fit[c] < fit[best] {
                    best = c;
                }
            }
            best
        };
        let p1 = pick(&mut rng, &fit);
        let p2 = pick(&mut rng, &fit);

        // Uniform crossover: k'_j from parent 1 or 2 with prob 1/2.
        let mut child: Vec<u32> = (0..n_layers)
            .map(|j| {
                if rng.gen_f64() < 0.5 {
                    pop[p1].k[j]
                } else {
                    pop[p2].k[j]
                }
            })
            .collect();

        // Budget-preserving mutation: paired +1/-1 moves (sum Δ_j = 0).
        let n_pairs = ((n_layers as f64 * params.mutation_rate / 2.0).ceil()) as usize;
        for _ in 0..n_pairs {
            if rng.gen_f64() > params.mutation_rate {
                continue;
            }
            let up: Vec<usize> = (0..n_layers).filter(|&j| child[j] < bounds.k_max).collect();
            let dn: Vec<usize> = (0..n_layers).filter(|&j| child[j] > bounds.k_min).collect();
            if up.is_empty() || dn.is_empty() {
                break;
            }
            let u = up[rng.gen_usize(up.len())];
            let d = dn[rng.gen_usize(dn.len())];
            if u != d {
                child[u] += 1;
                child[d] -= 1;
            }
        }

        // Projection (crossover can break the budget even when both
        // parents satisfy it).
        let mut child = Allocation::new(child);
        child.project(bounds, budget, &mut rng);
        debug_assert!(child.satisfies(bounds, budget));

        // Steady-state replacement: child replaces the current worst if
        // it improves on it.
        let cf = table.fitness(&child.k);
        evaluations += 1;
        let worst = (0..fit.len())
            .max_by(|&a, &b| fit[a].partial_cmp(&fit[b]).unwrap())
            .unwrap();
        if cf < fit[worst] {
            pop[worst] = child;
            fit[worst] = cf;
        }
        let best = fit.iter().cloned().fold(f64::INFINITY, f64::min);
        history.push(best);
    }

    let best_idx = (0..fit.len())
        .min_by(|&a, &b| fit[a].partial_cmp(&fit[b]).unwrap())
        .unwrap();
    Some(EvolutionResult {
        best: pop[best_idx].clone(),
        best_fitness: fit[best_idx],
        history,
        evaluations,
    })
}

/// Exhaustive optimum by dynamic programming over (layer, remaining
/// budget) — O(L * B * k_base). Used to validate GA quality in tests and
/// as an exact solver for small models.
pub fn exact_dp(table: &SensitivityTable, budget: u32, bounds: Bounds) -> Option<Allocation> {
    let l = table.n_layers();
    let b = budget as usize;
    let lo = bounds.k_min as usize;
    let hi = bounds.k_max as usize;
    if b < lo * l || b > hi * l {
        return None;
    }
    const INF: f64 = f64::INFINITY;
    // dp[j][r] = min cost of layers j.. with r budget remaining
    let mut dp = vec![vec![INF; b + 1]; l + 1];
    dp[l][0] = 0.0;
    for j in (0..l).rev() {
        for r in 0..=b {
            let mut best = INF;
            for k in lo..=hi.min(r) {
                let rest = r - k;
                if dp[j + 1][rest].is_finite() {
                    let c = table.d(j, k as u32) + dp[j + 1][rest];
                    if c < best {
                        best = c;
                    }
                }
            }
            dp[j][r] = best;
        }
    }
    if !dp[0][b].is_finite() {
        return None;
    }
    // reconstruct
    let mut k_out = Vec::with_capacity(l);
    let mut r = b;
    for j in 0..l {
        for k in lo..=hi.min(r) {
            let rest = r - k;
            if (table.d(j, k as u32) + dp[j + 1][rest] - dp[j][r]).abs() < 1e-9 {
                k_out.push(k as u32);
                r = rest;
                break;
            }
        }
    }
    debug_assert_eq!(k_out.len(), l);
    Some(Allocation::new(k_out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SensitivityTable {
        SensitivityTable::synthetic("t", 16, 8, |x| 1.0 + 3.0 * x, 3)
    }

    #[test]
    fn ga_returns_feasible_best() {
        let t = table();
        let bounds = Bounds::paper(8);
        let params = EvolutionParams {
            generations: 300,
            ..Default::default()
        };
        let res = evolve(&t, 80, bounds, &params).unwrap();
        assert!(res.best.satisfies(bounds, 80));
        // convergence curve is non-increasing
        for w in res.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn ga_close_to_exact_dp() {
        let t = table();
        let bounds = Bounds::paper(8);
        let params = EvolutionParams {
            generations: 2000,
            ..Default::default()
        };
        let ga = evolve(&t, 64, bounds, &params).unwrap();
        let dp = exact_dp(&t, 64, bounds).unwrap();
        let opt = t.fitness(&dp.k);
        assert!(
            ga.best_fitness <= opt * 1.05 + 1e-9,
            "GA {} vs DP {}",
            ga.best_fitness,
            opt
        );
    }

    #[test]
    fn ga_allocates_k_to_sensitive_layers() {
        // deep layers 4x more sensitive -> they should keep higher k
        let t = SensitivityTable::synthetic("t", 12, 4, |x| 0.5 + 4.0 * x, 9);
        let res = evolve(&t, 30, Bounds::paper(4), &EvolutionParams::default()).unwrap();
        let front: u32 = res.best.k[..6].iter().sum();
        let back: u32 = res.best.k[6..].iter().sum();
        assert!(back > front, "k {:?}", res.best.k);
    }

    #[test]
    fn infeasible_budget_is_none() {
        let t = table();
        assert!(evolve(&t, 5, Bounds::paper(8), &EvolutionParams::default()).is_none());
        assert!(exact_dp(&t, 5, Bounds::paper(8)).is_none());
    }

    #[test]
    fn full_budget_recovers_baseline() {
        let t = table();
        let res = evolve(&t, 16 * 8, Bounds::paper(8), &EvolutionParams::default()).unwrap();
        assert_eq!(res.best.k, vec![8; 16]);
        assert!(res.best_fitness.abs() < 1e-9);
    }
}
