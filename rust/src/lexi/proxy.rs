//! Stage-1 output: the per-layer top-k perturbation-loss table
//! `D[layer][k]` (Alg. 1's `D̄_k` per layer), the proxy Stage 2 minimizes.

use crate::util::json::Json;
use crate::util::Pcg32;

/// `loss[j][k-1]` = mean Frobenius deviation of layer j at top-k = k,
/// relative to the layer's baseline top-k output.
#[derive(Clone, Debug, PartialEq)]
pub struct SensitivityTable {
    pub model: String,
    pub k_base: u32,
    /// [n_layers][k_base]; entry (j, k-1) is D_j(k).
    pub loss: Vec<Vec<f64>>,
    /// Monte-Carlo iterations behind each entry.
    pub iters: usize,
}

impl SensitivityTable {
    pub fn n_layers(&self) -> usize {
        self.loss.len()
    }

    /// D_j(k); k is 1-based as in the paper.
    pub fn d(&self, layer: usize, k: u32) -> f64 {
        self.loss[layer][(k - 1) as usize]
    }

    /// Alg. 2 fitness: phi(k) = sum_j D_j(k_j).
    pub fn fitness(&self, alloc: &[u32]) -> f64 {
        debug_assert_eq!(alloc.len(), self.n_layers());
        alloc
            .iter()
            .enumerate()
            .map(|(j, &k)| self.d(j, k))
            .sum()
    }

    /// Alg. 2 fitness at *fractional* per-layer k — the Stage-1 scale
    /// extended to quality-lattice points whose effective active
    /// experts are non-integer (intra-expert pruning scales capacity,
    /// dynamic skipping sheds expected experts). Linear interpolation
    /// between the bracketing integer entries, clamped to [1, k_base].
    pub fn fitness_fractional(&self, k_eff: &[f64]) -> f64 {
        debug_assert_eq!(k_eff.len(), self.n_layers());
        k_eff
            .iter()
            .enumerate()
            .map(|(j, &k)| {
                let k = k.clamp(1.0, self.k_base as f64);
                let lo = k.floor() as u32;
                let hi = k.ceil() as u32;
                if lo == hi {
                    return self.d(j, lo);
                }
                let w = k - lo as f64;
                self.d(j, lo) * (1.0 - w) + self.d(j, hi) * w
            })
            .sum()
    }

    /// Row-normalized copy for heatmap rendering (Fig. 3/9 plots
    /// "normalized sensitivity").
    pub fn normalized(&self) -> Vec<Vec<f64>> {
        self.loss
            .iter()
            .map(|row| {
                let max = row.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
                row.iter().map(|v| v / max).collect()
            })
            .collect()
    }

    /// Synthetic table with a chosen depth profile — used by unit tests and
    /// benches so Stage 2 can be exercised without artifacts. `profile`
    /// maps normalized depth in [0,1] to a layer sensitivity scale.
    pub fn synthetic<F: Fn(f64) -> f64>(
        model: &str,
        n_layers: usize,
        k_base: u32,
        profile: F,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let loss = (0..n_layers)
            .map(|j| {
                let x = j as f64 / (n_layers.max(2) - 1) as f64;
                let scale = profile(x).max(1e-3);
                (1..=k_base)
                    .map(|k| {
                        // deviation decreases in k and vanishes at k_base
                        let gap = (k_base - k) as f64 / k_base as f64;
                        scale * gap.powf(1.3) * (1.0 + 0.05 * rng.gen_normal())
                    })
                    .map(|v| v.max(0.0))
                    .collect()
            })
            .collect();
        SensitivityTable {
            model: model.to_string(),
            k_base,
            loss,
            iters: 0,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("k_base", Json::Num(self.k_base as f64)),
            ("iters", Json::Num(self.iters as f64)),
            (
                "loss",
                Json::Arr(self.loss.iter().map(|row| Json::from_f64s(row)).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(SensitivityTable {
            model: v.get("model")?.as_str()?.to_string(),
            k_base: v.get("k_base")?.as_usize()? as u32,
            iters: v.get("iters")?.as_usize()?,
            loss: v
                .get("loss")?
                .as_arr()?
                .iter()
                .map(|row| row.f64_vec())
                .collect::<anyhow::Result<_>>()?,
        })
    }

    pub fn save_json(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load_json(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_json(&crate::util::json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitness_sums_rows() {
        let t = SensitivityTable {
            model: "m".into(),
            k_base: 2,
            loss: vec![vec![3.0, 0.0], vec![5.0, 0.0]],
            iters: 1,
        };
        assert_eq!(t.fitness(&[1, 1]), 8.0);
        assert_eq!(t.fitness(&[2, 2]), 0.0);
        assert_eq!(t.fitness(&[1, 2]), 3.0);
    }

    #[test]
    fn fractional_fitness_interpolates_and_clamps() {
        let t = SensitivityTable {
            model: "m".into(),
            k_base: 2,
            loss: vec![vec![3.0, 0.0], vec![5.0, 1.0]],
            iters: 1,
        };
        // integer points match the exact fitness
        assert_eq!(t.fitness_fractional(&[1.0, 1.0]), t.fitness(&[1, 1]));
        assert_eq!(t.fitness_fractional(&[2.0, 2.0]), t.fitness(&[2, 2]));
        // halfway between the entries: (3+0)/2 + (5+1)/2
        assert!((t.fitness_fractional(&[1.5, 1.5]) - 4.5).abs() < 1e-12);
        // out-of-range effective k clamps to the table bounds
        assert_eq!(t.fitness_fractional(&[0.2, 9.0]), t.fitness(&[1, 2]));
        // monotone: shedding experts never reduces the proxy loss
        assert!(t.fitness_fractional(&[1.7, 1.7]) > t.fitness_fractional(&[1.9, 1.9]));
    }

    #[test]
    fn synthetic_monotone_and_zero_at_kbase() {
        let t = SensitivityTable::synthetic("m", 8, 6, |x| 1.0 + x, 0);
        for row in &t.loss {
            assert!(row[5].abs() < 1e-9);
            for w in row.windows(2) {
                assert!(w[0] >= w[1] - 1e-9, "not monotone: {row:?}");
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = SensitivityTable::synthetic("m", 4, 3, |_| 1.0, 1);
        let path = std::env::temp_dir().join("lexi_proxy_test.json");
        t.save_json(&path).unwrap();
        let u = SensitivityTable::load_json(&path).unwrap();
        assert_eq!(t, u);
    }
}
