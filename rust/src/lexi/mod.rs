//! The paper's contribution: LExI's two-stage pipeline.
//!
//! Stage 1 ([`sensitivity`]) — Alg. 1: data-free Monte-Carlo profiling of
//! each MoE layer's output deviation (Frobenius norm) under every
//! candidate top-k, using only the model's weights and N(0,1) inputs.
//!
//! Stage 2 ([`evolution`]) — Alg. 2: evolutionary search over per-layer
//! allocations under a global active-expert budget, using the Stage-1
//! table as a fitness proxy (no model loads inside the loop).

pub mod evolution;
pub mod pipeline;
pub mod proxy;
pub mod sensitivity;

pub use evolution::{EvolutionParams, EvolutionResult, evolve};
pub use proxy::SensitivityTable;
