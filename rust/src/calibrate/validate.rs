//! Measure → fit → cross-validate: the drivers behind `lexi calibrate`
//! and `lexi cross-validate`.
//!
//! Both commands replay the SAME seeded scenario trace (generated once,
//! from the analytical baseline service model, so it is identical for
//! every backend) through engine-backed replicas. `calibrate` buckets
//! the measured step samples into a [`CalibrationArtifact`];
//! `cross-validate` additionally replays the trace on the virtual-time
//! sim twice — raw (analytical service models) and calibrated (service
//! models refit from the artifact) — and reports per-percentile
//! TTFT/TPOT divergence plus served-token parity between the backends.
//!
//! The pass/fail gate reads the BASELINE contender (single rung, no
//! adaptive controller): its latency distribution is a pure function of
//! the service model and the shared queueing discipline, so divergence
//! there measures calibration quality, not rung-switch timing noise.
//! The adaptive lexi-ladder contender is measured and reported alongside
//! (it is what visits the deeper rungs during calibration) but does not
//! gate. p50/p95 gate; p99 is reported but ungated by default — at
//! CI-sized traces it is a near-max order statistic. `--gate-p99` opts
//! the p99 column into the gate for runs long enough to trust it. All
//! percentiles come from the shared [`crate::obs::Quantiles`]
//! implementation, so the gate and every report agree bit-for-bit.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::model::ModelSpec;
use crate::config::server::ServerConfig;
use crate::obs::Quantiles;
use crate::server::report::meets_slo;
use crate::server::{
    self, Contender, QualityLadder, RunResult, Scenario, Trace, TransformReport,
};
use crate::util::json::Json;

use super::fit::apply_to_ladder;
use super::observe::{artifact_path, CalibrationArtifact};

/// Percentiles tracked per metric (order matters: `GATED` indexes it).
pub const PERCENTILES: [f64; 3] = [50.0, 95.0, 99.0];
/// Indices of [`PERCENTILES`] that participate in the pass/fail gate.
pub const GATED: [usize; 2] = [0, 1];
/// Default relative-divergence tolerance of the gate.
pub const DEFAULT_TOLERANCE: f64 = 0.5;

/// One backend's latency/goodput summary over the shared trace.
#[derive(Clone, Debug, PartialEq)]
pub struct BackendSummary {
    pub n_completed: usize,
    /// Generated tokens over all completions (the parity quantity).
    pub served_tokens: u64,
    pub goodput_rps: f64,
    pub throughput_tok_s: f64,
    pub makespan_s: f64,
    /// TTFT at [`PERCENTILES`].
    pub ttft_s: [f64; 3],
    /// TPOT at [`PERCENTILES`].
    pub tpot_s: [f64; 3],
}

impl BackendSummary {
    fn from_run(res: &RunResult, scenario: &Scenario) -> Self {
        // the shared exact-percentile implementation (see crate::obs)
        let ttft = Quantiles::from_samples(res.completed.iter().map(|c| c.ttft_s));
        let tpot = Quantiles::from_samples(res.completed.iter().map(|c| c.tpot_s()));
        let pct = |q: &Quantiles| -> [f64; 3] { std::array::from_fn(|i| q.q(PERCENTILES[i])) };
        let makespan = res.makespan_s.max(1e-9);
        let n_slo_met = res
            .completed
            .iter()
            .filter(|c| meets_slo(c, &scenario.slos[c.class]))
            .count();
        let total_tokens: usize = res.completed.iter().map(|c| c.prompt_len + c.tokens).sum();
        BackendSummary {
            n_completed: res.completed.len(),
            served_tokens: res.completed.iter().map(|c| c.tokens as u64).sum(),
            goodput_rps: n_slo_met as f64 / makespan,
            throughput_tok_s: total_tokens as f64 / makespan,
            makespan_s: makespan,
            ttft_s: pct(&ttft),
            tpot_s: pct(&tpot),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_completed", Json::Num(self.n_completed as f64)),
            ("served_tokens", Json::Num(self.served_tokens as f64)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s)),
            ("makespan_s", Json::Num(self.makespan_s)),
            ("ttft_s", Json::from_f64s(&self.ttft_s)),
            ("tpot_s", Json::from_f64s(&self.tpot_s)),
        ])
    }
}

/// Relative per-percentile divergence of one sim run from the engine
/// run: `|sim − engine| / engine`.
#[derive(Clone, Debug, PartialEq)]
pub struct Divergence {
    pub ttft: [f64; 3],
    pub tpot: [f64; 3],
}

impl Divergence {
    pub fn between(sim: &BackendSummary, eng: &BackendSummary) -> Self {
        let rel = |s: f64, e: f64| (s - e).abs() / e.max(1e-9);
        let row = |s: &[f64; 3], e: &[f64; 3]| -> [f64; 3] {
            std::array::from_fn(|i| rel(s[i], e[i]))
        };
        Divergence {
            ttft: row(&sim.ttft_s, &eng.ttft_s),
            tpot: row(&sim.tpot_s, &eng.tpot_s),
        }
    }

    /// Worst divergence over the gated percentiles of both metrics.
    pub fn max_gated(&self) -> f64 {
        self.max_gated_with(false)
    }

    /// [`max_gated`](Divergence::max_gated), optionally extending the
    /// gate to the p99 column (`--gate-p99`).
    pub fn max_gated_with(&self, gate_p99: bool) -> f64 {
        let idxs: &[usize] = if gate_p99 { &[0, 1, 2] } else { &GATED };
        idxs.iter()
            .flat_map(|&i| [self.ttft[i], self.tpot[i]])
            .fold(0.0, f64::max)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ttft", Json::from_f64s(&self.ttft)),
            ("tpot", Json::from_f64s(&self.tpot)),
            ("max_gated", Json::Num(self.max_gated())),
        ])
    }
}

/// Engine vs. raw-sim vs. calibrated-sim comparison of one contender.
#[derive(Clone, Debug)]
pub struct ContenderValidation {
    pub label: String,
    pub engine: BackendSummary,
    pub sim_raw: BackendSummary,
    pub sim_calibrated: BackendSummary,
    pub raw: Divergence,
    pub calibrated: Divergence,
    /// Per-request generated-token maps of engine and both sims agree
    /// exactly (the "what was served" half of cross-validation).
    pub token_parity: bool,
}

/// The full `lexi cross-validate` outcome.
#[derive(Clone, Debug)]
pub struct CrossValidation {
    pub model: String,
    pub scenario: String,
    pub seed: u64,
    pub tolerance: f64,
    /// Whether the p99 column participated in the gate (`--gate-p99`).
    pub gate_p99: bool,
    /// Rungs of the lexi ladder whose service models were refit.
    pub calibrated_rungs: Vec<usize>,
    pub contenders: Vec<ContenderValidation>,
    /// Gate: token parity on every contender AND the baseline
    /// contender's calibrated divergence within tolerance at the gated
    /// percentiles.
    pub pass: bool,
}

impl CrossValidation {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("tolerance", Json::Num(self.tolerance)),
            ("gate_p99", Json::Bool(self.gate_p99)),
            ("percentiles", Json::from_f64s(&PERCENTILES)),
            (
                "calibrated_rungs",
                Json::Arr(
                    self.calibrated_rungs
                        .iter()
                        .map(|&r| Json::Num(r as f64))
                        .collect(),
                ),
            ),
            (
                "contenders",
                Json::Arr(
                    self.contenders
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("label", Json::Str(c.label.clone())),
                                ("engine", c.engine.to_json()),
                                ("sim_raw", c.sim_raw.to_json()),
                                ("sim_calibrated", c.sim_calibrated.to_json()),
                                ("divergence_raw", c.raw.to_json()),
                                ("divergence_calibrated", c.calibrated.to_json()),
                                ("token_parity", Json::Bool(c.token_parity)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("pass", Json::Bool(self.pass)),
        ])
    }
}

/// One engine-backed measurement pass: the calibration line-up (fixed
/// baseline + adaptive lexi ladder), the shared scenario trace, the
/// engine run results, and the artifact bucketed from their samples.
pub(crate) struct EngineCollection {
    pub line_up: Vec<Contender>,
    pub scenario: Scenario,
    pub trace: Trace,
    pub runs: Vec<(TransformReport, RunResult)>,
    pub artifact: CalibrationArtifact,
}

/// Build the calibration line-up and replay the seeded scenario on the
/// engine backend, bucketing every measured step into an artifact. The
/// baseline contender feeds rung 0 alongside the ladder run (its rung is
/// the same k_vec), so rung 0 — the gate's rung — gets the most data.
pub(crate) fn collect(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    artifacts: Option<&Path>,
) -> Result<EngineCollection> {
    let (table, source) =
        server::sensitivity_table_sourced(spec, artifacts, cfg.seed, cfg.table_mode)?;
    println!("ladder Stage-1 table source: {source}");
    let pm = crate::perfmodel::PerfModel::new(spec.clone(), cfg.seed);
    let full = QualityLadder::for_model(spec, &table, cfg, &pm)?;
    let baseline = QualityLadder::fixed(
        "base",
        full.points()[0].allocation.clone(),
        full.points()[0].service.clone(),
    );
    let line_up = vec![
        Contender {
            label: "baseline",
            ladder: baseline,
            adaptive: false,
        },
        Contender {
            label: "lexi-ladder",
            ladder: full.clone(),
            adaptive: true,
        },
    ];
    let (scenario, trace) = server::scenario_and_trace(&full.points()[0].service, cfg)?;

    let (runs, engine_source) = match server::try_real_runtime(spec, artifacts) {
        Some(model) => {
            println!("engine backend: compiled PJRT runtime ({})", spec.name);
            (
                server::engine_runs(spec, &model, &line_up, &scenario, &trace, cfg)?,
                "engine-pjrt",
            )
        }
        None => {
            let model = server::synthetic_engine_model(spec, cfg, &scenario);
            (
                server::engine_runs(spec, &model, &line_up, &scenario, &trace, cfg)?,
                "engine-synthetic",
            )
        }
    };

    let mut artifact = CalibrationArtifact::new(
        spec.name,
        scenario.name,
        cfg.seed,
        cfg.replicas,
        cfg.slots_per_replica,
        engine_source,
        full.n_rungs(),
    );
    for (_, res) in &runs {
        for samples in res.step_samples_per_replica.iter().flatten() {
            artifact.record_all(samples.iter());
        }
    }
    anyhow::ensure!(
        artifact.n_samples() > 0,
        "engine run produced no step samples to calibrate from"
    );
    Ok(EngineCollection {
        line_up,
        scenario,
        trace,
        runs,
        artifact,
    })
}

/// `lexi calibrate`: measure, bucket, fit, and write the artifact.
/// Returns the artifact and the path it was written to.
pub fn calibrate(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    artifacts: Option<&Path>,
    out_dir: &Path,
) -> Result<(CalibrationArtifact, PathBuf)> {
    let col = collect(spec, cfg, artifacts)?;
    print_fit_summary(&col.artifact);
    let path = artifact_path(out_dir, spec.name, col.scenario.name);
    col.artifact.save(&path)?;
    println!("calibration artifact written to {}", path.display());
    Ok((col.artifact, path))
}

/// Print each observed rung's fitted coefficients.
pub fn print_fit_summary(art: &CalibrationArtifact) {
    println!(
        "calibration: {} samples over {} rungs (source {})",
        art.n_samples(),
        art.rungs.len(),
        art.source
    );
    for (j, rs) in art.rungs.iter().enumerate() {
        if rs.n_samples() == 0 {
            println!("  rung {j}: no samples (analytical service model retained)");
            continue;
        }
        let fit = super::fit::fit_rung(rs);
        let pf = fit
            .prefill
            .map(|t| {
                format!(
                    "overhead {:.3}ms + {:.4}us/token (n={})",
                    t.base_s * 1e3,
                    t.per_x_s * 1e6,
                    t.n
                )
            })
            .unwrap_or_else(|| "no samples".to_string());
        let df = fit
            .decode
            .map(|t| {
                format!(
                    "base {:.3}ms + {:.4}ms/slot (n={})",
                    t.base_s * 1e3,
                    t.per_x_s * 1e3,
                    t.n
                )
            })
            .unwrap_or_else(|| "no samples".to_string());
        println!("  rung {j}: prefill {pf}; decode {df}");
        if fit.prefill_stall_s > 0.0 || fit.decode_stall_s > 0.0 {
            println!(
                "  rung {j}: residency stall/step prefill {:.3}ms decode {:.3}ms",
                fit.prefill_stall_s * 1e3,
                fit.decode_stall_s * 1e3
            );
        }
    }
}

fn token_map(res: &RunResult) -> BTreeMap<u64, usize> {
    res.completed.iter().map(|c| (c.id, c.tokens)).collect()
}

/// `lexi cross-validate`: replay the same seeded trace on the engine
/// backend and on the sim backend twice (analytical and calibrated
/// service models), then compare latency distributions and served
/// tokens. `calibration_file` reuses a saved artifact for the sim refit;
/// without it the engine run's own samples are fitted inline. `gate_p99`
/// extends the gate to the p99 column; `append` adds one compact entry
/// to a perf-trajectory file (CI's `BENCH_serve.json`, kept in git).
pub fn cross_validate(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    artifacts: Option<&Path>,
    calibration_file: Option<&Path>,
    tolerance: f64,
    gate_p99: bool,
    append: Option<&Path>,
    out_dir: &Path,
) -> Result<CrossValidation> {
    anyhow::ensure!(tolerance > 0.0, "--tolerance must be > 0");
    // validate a supplied artifact BEFORE the expensive engine pass, so
    // a mismatched file fails in milliseconds, not minutes
    let supplied = match calibration_file {
        Some(p) => {
            let art = CalibrationArtifact::load(p)?;
            art.ensure_matches(spec.name, cfg)
                .with_context(|| format!("applying calibration artifact {}", p.display()))?;
            Some(art)
        }
        None => None,
    };
    let col = collect(spec, cfg, artifacts)?;
    let artifact = supplied.unwrap_or_else(|| col.artifact.clone());

    // raw sim: the analytical service models, exactly as bench-serve
    let raw_runs = server::sim_runs(spec, &col.line_up, &col.scenario, &col.trace, cfg);

    // calibrated sim: same contenders, service models refit per rung
    let mut cal_line_up: Vec<Contender> = col.line_up.clone();
    let mut calibrated_rungs = Vec::new();
    for c in &mut cal_line_up {
        let applied = apply_to_ladder(&mut c.ladder, &artifact, false);
        if c.label == "lexi-ladder" {
            calibrated_rungs = applied;
        }
    }
    let cal_runs = server::sim_runs(spec, &cal_line_up, &col.scenario, &col.trace, cfg);

    let mut contenders = Vec::new();
    for (i, (_, eng_res)) in col.runs.iter().enumerate() {
        let eng = BackendSummary::from_run(eng_res, &col.scenario);
        let raw = BackendSummary::from_run(&raw_runs[i].1, &col.scenario);
        let cal = BackendSummary::from_run(&cal_runs[i].1, &col.scenario);
        let token_parity = token_map(eng_res) == token_map(&raw_runs[i].1)
            && token_map(eng_res) == token_map(&cal_runs[i].1);
        contenders.push(ContenderValidation {
            label: col.line_up[i].label.to_string(),
            raw: Divergence::between(&raw, &eng),
            calibrated: Divergence::between(&cal, &eng),
            engine: eng,
            sim_raw: raw,
            sim_calibrated: cal,
            token_parity,
        });
    }

    let gate = &contenders[0]; // baseline (see module docs)
    let pass = contenders.iter().all(|c| c.token_parity)
        && gate.calibrated.max_gated_with(gate_p99) <= tolerance;
    let cv = CrossValidation {
        model: spec.name.to_string(),
        scenario: col.scenario.name.to_string(),
        seed: cfg.seed,
        tolerance,
        gate_p99,
        calibrated_rungs,
        contenders,
        pass,
    };

    print_cross_validation(&cv);
    std::fs::create_dir_all(out_dir)?;
    let report_path = out_dir.join(format!("cross_validate_{}_{}.json", cv.model, cv.scenario));
    std::fs::write(&report_path, cv.to_json().to_string_pretty())
        .with_context(|| format!("writing {}", report_path.display()))?;
    write_bench_summary(&cv, &out_dir.join("BENCH_serve.json"))?;
    if let Some(traj) = append {
        crate::obs::append_trajectory(traj, "serve-trajectory", trajectory_entry(&cv))?;
        println!("trajectory entry appended to {}", traj.display());
    }
    crate::figures::cross_validation::divergence_figure(&cv).emit(out_dir)?;
    println!("cross-validation report written to {}", report_path.display());
    Ok(cv)
}

fn print_cross_validation(cv: &CrossValidation) {
    println!(
        "\n=== cross-validation: {} / {} (seed {}, tolerance {:.0}%) ===",
        cv.model,
        cv.scenario,
        cv.seed,
        cv.tolerance * 100.0
    );
    for c in &cv.contenders {
        println!(
            "{:<12} engine ttft p50/p95 {:.1}/{:.1}ms tpot p50 {:.2}ms | \
             raw div {:.0}% | calibrated div {:.0}% | token parity {}",
            c.label,
            c.engine.ttft_s[0] * 1e3,
            c.engine.ttft_s[1] * 1e3,
            c.engine.tpot_s[0] * 1e3,
            c.raw.max_gated() * 100.0,
            c.calibrated.max_gated() * 100.0,
            if c.token_parity { "ok" } else { "BROKEN" },
        );
    }
    println!(
        "gate ({}, ttft/tpot {}): {}",
        cv.contenders[0].label,
        if cv.gate_p99 {
            "p50+p95+p99"
        } else {
            "p50+p95"
        },
        if cv.pass { "PASS" } else { "FAIL" }
    );
}

/// One compact perf-trajectory row per cross-validation run: enough to
/// chart goodput/divergence over commits without the full report.
fn trajectory_entry(cv: &CrossValidation) -> Json {
    let base = &cv.contenders[0];
    Json::obj(vec![
        ("model", Json::Str(cv.model.clone())),
        ("scenario", Json::Str(cv.scenario.clone())),
        ("seed", Json::Num(cv.seed as f64)),
        ("pass", Json::Bool(cv.pass)),
        ("gate_p99", Json::Bool(cv.gate_p99)),
        (
            "max_divergence_calibrated",
            Json::Num(
                cv.contenders
                    .iter()
                    .map(|c| c.calibrated.max_gated())
                    .fold(0.0, f64::max),
            ),
        ),
        ("baseline_goodput_rps", Json::Num(base.engine.goodput_rps)),
        ("baseline_ttft_p99_s", Json::Num(base.engine.ttft_s[2])),
        ("baseline_tpot_p99_s", Json::Num(base.engine.tpot_s[2])),
    ])
}

/// The CI perf-trajectory summary: goodput + latency of every backend
/// variant, plus the gate verdict, in one flat artifact.
fn write_bench_summary(cv: &CrossValidation, path: &Path) -> Result<()> {
    let v = Json::obj(vec![
        ("bench", Json::Str("cross_validate".to_string())),
        ("model", Json::Str(cv.model.clone())),
        ("scenario", Json::Str(cv.scenario.clone())),
        ("seed", Json::Num(cv.seed as f64)),
        ("tolerance", Json::Num(cv.tolerance)),
        ("pass", Json::Bool(cv.pass)),
        (
            "max_divergence_raw",
            Json::Num(
                cv.contenders
                    .iter()
                    .map(|c| c.raw.max_gated())
                    .fold(0.0, f64::max),
            ),
        ),
        (
            "max_divergence_calibrated",
            Json::Num(
                cv.contenders
                    .iter()
                    .map(|c| c.calibrated.max_gated())
                    .fold(0.0, f64::max),
            ),
        ),
        (
            "contenders",
            Json::Arr(
                cv.contenders
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("label", Json::Str(c.label.clone())),
                            ("engine", c.engine.to_json()),
                            ("sim_raw", c.sim_raw.to_json()),
                            ("sim_calibrated", c.sim_calibrated.to_json()),
                            ("divergence_calibrated", c.calibrated.to_json()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(path, v.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("serving summary written to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::CompletedRequest;

    fn run_with(ttfts: &[f64]) -> RunResult {
        RunResult {
            completed: ttfts
                .iter()
                .enumerate()
                .map(|(i, &t)| CompletedRequest {
                    id: i as u64,
                    class: 0,
                    arrival_s: 0.0,
                    prompt_len: 64,
                    tokens: 16,
                    ttft_s: t,
                    e2e_s: t + 0.15,
                    finish_s: t + 0.15,
                    replica: 0,
                })
                .collect(),
            rejected_by_class: vec![0],
            makespan_s: 10.0,
            replica_busy_s: vec![5.0],
            rung_switches: 0,
            rung_time_s: vec![5.0],
            prefill_calls: 1,
            decode_steps: 10,
            rung_switch_events: vec![],
            steal_events: vec![],
            steals: None,
            min_slack_s: None,
            step_time_per_replica: vec![None],
            step_samples_per_replica: vec![None],
            residency_per_replica: vec![None],
            shed_by_class: None,
            replica_seconds: None,
            scale_events: None,
            trace: None,
            health: None,
        }
    }

    fn scenario() -> Scenario {
        let mut s = Scenario::from_kind(crate::config::server::ScenarioKind::Poisson, 10.0);
        s.resolve_slos(|_| 10.0, 10.0);
        s
    }

    #[test]
    fn summary_and_divergence_math() {
        let s = scenario();
        let eng = BackendSummary::from_run(&run_with(&[0.1, 0.2, 0.3, 0.4]), &s);
        assert_eq!(eng.n_completed, 4);
        assert_eq!(eng.served_tokens, 64);
        assert!((eng.ttft_s[0] - 0.25).abs() < 1e-9);
        // tpot = 0.15 / 15 = 0.01 for every request
        assert!((eng.tpot_s[0] - 0.01).abs() < 1e-12);

        let sim = BackendSummary::from_run(&run_with(&[0.15, 0.3, 0.45, 0.6]), &s);
        let d = Divergence::between(&sim, &eng);
        // every ttft percentile off by exactly +50%, tpot identical
        for i in 0..3 {
            assert!((d.ttft[i] - 0.5).abs() < 1e-9, "p{i}: {}", d.ttft[i]);
            assert!(d.tpot[i] < 1e-9);
        }
        assert!((d.max_gated() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cross_validation_json_shape() {
        let s = scenario();
        let eng = BackendSummary::from_run(&run_with(&[0.1, 0.2]), &s);
        let sim = BackendSummary::from_run(&run_with(&[0.1, 0.2]), &s);
        let c = ContenderValidation {
            label: "baseline".into(),
            raw: Divergence::between(&sim, &eng),
            calibrated: Divergence::between(&sim, &eng),
            engine: eng,
            sim_raw: sim.clone(),
            sim_calibrated: sim,
            token_parity: true,
        };
        let cv = CrossValidation {
            model: "m".into(),
            scenario: "poisson".into(),
            seed: 7,
            tolerance: 0.5,
            gate_p99: false,
            calibrated_rungs: vec![0, 1],
            contenders: vec![c],
            pass: true,
        };
        let j = cv.to_json();
        assert!(j.get("pass").unwrap().as_bool().unwrap());
        let arr = j.get("contenders").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("label").unwrap().as_str().unwrap(), "baseline");
        assert!(arr[0]
            .get("divergence_calibrated")
            .unwrap()
            .get("max_gated")
            .unwrap()
            .as_f64()
            .unwrap()
            .abs()
            < 1e-9);
        // round-trips through the parser
        let re = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(re.get("seed").unwrap().as_usize().unwrap(), 7);
    }
}
