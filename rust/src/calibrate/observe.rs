//! Calibration observations: occupancy-bucketed step-time statistics
//! accumulated from the engine backend's measured [`StepSample`] stream.
//!
//! Buckets keep full second-moment sums (`n`, `Σx`, `Σx²`, `Σy`, `Σxy`,
//! `Σstall`), so the fitter's weighted least squares over buckets is
//! *exactly* the least squares over the raw samples — bucketing bounds
//! the artifact size without losing regression information. The artifact
//! serializes through the repo's own `util::json` (the build environment
//! has no serde; the writer emits shortest-round-trip `f64`s, so a
//! save/load cycle reproduces the sums bit for bit).

use std::path::Path;

use anyhow::{Context, Result};

use crate::server::StepSample;
use crate::util::json::{self, Json};

/// Artifact schema version (bump on incompatible layout changes).
pub const ARTIFACT_VERSION: u32 = 1;

/// Prefill samples are bucketed by admitted prompt tokens at this
/// granularity; decode samples are bucketed by exact slot occupancy.
pub const PREFILL_BUCKET_TOKENS: u64 = 64;

/// Sufficient statistics of all samples whose regressor fell in one
/// bucket (`y` = measured compute seconds, `x` = the regressor).
#[derive(Clone, Debug, PartialEq)]
pub struct SampleBucket {
    /// Bucket key: slot occupancy (decode) or `tokens /
    /// PREFILL_BUCKET_TOKENS` (prefill).
    pub key: u64,
    pub n: u64,
    pub sum_x: f64,
    pub sum_x2: f64,
    pub sum_y: f64,
    pub sum_xy: f64,
    /// Simulated residency stall, summed separately from compute.
    pub sum_stall: f64,
}

impl SampleBucket {
    fn new(key: u64) -> Self {
        SampleBucket {
            key,
            n: 0,
            sum_x: 0.0,
            sum_x2: 0.0,
            sum_y: 0.0,
            sum_xy: 0.0,
            sum_stall: 0.0,
        }
    }

    fn absorb(&mut self, x: f64, y: f64, stall: f64) {
        self.n += 1;
        self.sum_x += x;
        self.sum_x2 += x * x;
        self.sum_y += y;
        self.sum_xy += x * y;
        self.sum_stall += stall;
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::Num(self.key as f64)),
            ("n", Json::Num(self.n as f64)),
            ("sum_x", Json::Num(self.sum_x)),
            ("sum_x2", Json::Num(self.sum_x2)),
            ("sum_y", Json::Num(self.sum_y)),
            ("sum_xy", Json::Num(self.sum_xy)),
            ("sum_stall", Json::Num(self.sum_stall)),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(SampleBucket {
            key: v.get("key")?.as_usize()? as u64,
            n: v.get("n")?.as_usize()? as u64,
            sum_x: v.get("sum_x")?.as_f64()?,
            sum_x2: v.get("sum_x2")?.as_f64()?,
            sum_y: v.get("sum_y")?.as_f64()?,
            sum_xy: v.get("sum_xy")?.as_f64()?,
            sum_stall: v.get("sum_stall")?.as_f64()?,
        })
    }
}

/// All observations of one quality-ladder rung, split by phase kind.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RungSamples {
    /// Prefill buckets, keyed by prompt-token bucket, sorted by key.
    pub prefill: Vec<SampleBucket>,
    /// Decode buckets, keyed by slot occupancy, sorted by key.
    pub decode: Vec<SampleBucket>,
}

impl RungSamples {
    pub fn n_samples(&self) -> u64 {
        self.prefill.iter().chain(&self.decode).map(|b| b.n).sum()
    }

    fn record(&mut self, s: &StepSample) {
        let (buckets, key) = if s.prefill {
            (&mut self.prefill, s.x as u64 / PREFILL_BUCKET_TOKENS)
        } else {
            (&mut self.decode, s.x as u64)
        };
        let idx = match buckets.binary_search_by_key(&key, |b| b.key) {
            Ok(i) => i,
            Err(i) => {
                buckets.insert(i, SampleBucket::new(key));
                i
            }
        };
        buckets[idx].absorb(s.x, s.dt_s, s.stall_s);
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "prefill",
                Json::Arr(self.prefill.iter().map(|b| b.to_json()).collect()),
            ),
            (
                "decode",
                Json::Arr(self.decode.iter().map(|b| b.to_json()).collect()),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let parse = |key: &str| -> Result<Vec<SampleBucket>> {
            v.get(key)?.as_arr()?.iter().map(SampleBucket::from_json).collect()
        };
        Ok(RungSamples {
            prefill: parse("prefill")?,
            decode: parse("decode")?,
        })
    }
}

/// The calibration artifact: everything the fitter needs to refit the
/// sim `ServiceModel` per rung, plus the provenance required to refuse
/// application to a mismatched run (model, slot count, seed, source).
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationArtifact {
    pub version: u32,
    pub model: String,
    pub scenario: String,
    pub seed: u64,
    pub replicas: usize,
    /// Decode slots per replica the samples were measured at.
    pub slots: usize,
    /// Which engine model produced the samples: `engine-pjrt` (compiled
    /// artifacts) or `engine-synthetic` (host model).
    pub source: String,
    /// Per-rung observations, indexed by quality-ladder rung. Rungs the
    /// engine run never visited stay empty — the fitter leaves their
    /// analytical service models in place.
    pub rungs: Vec<RungSamples>,
}

impl CalibrationArtifact {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        model: &str,
        scenario: &str,
        seed: u64,
        replicas: usize,
        slots: usize,
        source: &str,
        n_rungs: usize,
    ) -> Self {
        CalibrationArtifact {
            version: ARTIFACT_VERSION,
            model: model.to_string(),
            scenario: scenario.to_string(),
            seed,
            replicas,
            slots,
            source: source.to_string(),
            rungs: vec![RungSamples::default(); n_rungs.max(1)],
        }
    }

    /// Fold one measured step into its (rung, phase, occupancy) bucket.
    pub fn record(&mut self, s: &StepSample) {
        if s.rung >= self.rungs.len() {
            self.rungs.resize(s.rung + 1, RungSamples::default());
        }
        self.rungs[s.rung].record(s);
    }

    pub fn record_all<'a>(&mut self, samples: impl IntoIterator<Item = &'a StepSample>) {
        for s in samples {
            self.record(s);
        }
    }

    pub fn n_samples(&self) -> u64 {
        self.rungs.iter().map(|r| r.n_samples()).sum()
    }

    /// Rung indices with at least one observation.
    pub fn observed_rungs(&self) -> Vec<usize> {
        self.rungs
            .iter()
            .enumerate()
            .filter(|(_, r)| r.n_samples() > 0)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(self.version as f64)),
            ("model", Json::Str(self.model.clone())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("replicas", Json::Num(self.replicas as f64)),
            ("slots", Json::Num(self.slots as f64)),
            ("source", Json::Str(self.source.clone())),
            (
                "rungs",
                Json::Arr(self.rungs.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let version = v.get("version")?.as_usize()? as u32;
        anyhow::ensure!(
            version == ARTIFACT_VERSION,
            "calibration artifact version {version} != supported {ARTIFACT_VERSION}"
        );
        Ok(CalibrationArtifact {
            version,
            model: v.get("model")?.as_str()?.to_string(),
            scenario: v.get("scenario")?.as_str()?.to_string(),
            seed: v.get("seed")?.as_usize()? as u64,
            replicas: v.get("replicas")?.as_usize()?,
            slots: v.get("slots")?.as_usize()?,
            source: v.get("source")?.as_str()?.to_string(),
            rungs: v
                .get("rungs")?
                .as_arr()?
                .iter()
                .map(RungSamples::from_json)
                .collect::<Result<_>>()?,
        })
    }

    /// Refuse application to a run the fit cannot describe: the model
    /// and the slot count (the decode table's domain) must match
    /// exactly. Scenario/seed/replicas mismatches are legitimate
    /// transfer uses but change what the fit was exposed to, so they
    /// are surfaced as a notice instead of an error.
    pub fn ensure_matches(
        &self,
        model: &str,
        cfg: &crate::config::server::ServerConfig,
    ) -> Result<()> {
        anyhow::ensure!(
            self.model == model,
            "calibration artifact was fitted for '{}', not '{}'",
            self.model,
            model
        );
        anyhow::ensure!(
            self.slots == cfg.slots_per_replica,
            "calibration artifact was measured at {} slots/replica, run uses {}; \
             re-run `lexi calibrate` with the matching --slots",
            self.slots,
            cfg.slots_per_replica
        );
        if self.scenario != cfg.scenario.label() || self.seed != cfg.seed
            || self.replicas != cfg.replicas
        {
            println!(
                "calibration note: artifact measured on scenario '{}' seed {} with {} replicas \
                 (run: '{}' seed {} with {}) — transferring the fit across workloads",
                self.scenario,
                self.seed,
                self.replicas,
                cfg.scenario.label(),
                cfg.seed,
                cfg.replicas
            );
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing calibration artifact {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&json::parse_file(path)?)
            .with_context(|| format!("loading calibration artifact {}", path.display()))
    }
}

/// Canonical artifact file name for a (model, scenario) pair.
pub fn artifact_path(out_dir: &Path, model: &str, scenario: &str) -> std::path::PathBuf {
    out_dir.join(format!("calibration_{model}_{scenario}.json"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(prefill: bool, rung: usize, x: f64, dt: f64, stall: f64) -> StepSample {
        StepSample {
            prefill,
            rung,
            x,
            dt_s: dt,
            stall_s: stall,
        }
    }

    #[test]
    fn buckets_accumulate_sufficient_statistics() {
        let mut art = CalibrationArtifact::new("m", "poisson", 0, 2, 4, "engine-synthetic", 2);
        art.record(&sample(false, 0, 2.0, 0.01, 0.0));
        art.record(&sample(false, 0, 2.0, 0.03, 0.002));
        art.record(&sample(false, 0, 4.0, 0.05, 0.0));
        art.record(&sample(true, 0, 100.0, 0.2, 0.0));
        assert_eq!(art.n_samples(), 4);
        let r0 = &art.rungs[0];
        assert_eq!(r0.decode.len(), 2); // occupancy 2 and 4
        let b2 = &r0.decode[0];
        assert_eq!((b2.key, b2.n), (2, 2));
        assert!((b2.sum_x - 4.0).abs() < 1e-12);
        assert!((b2.sum_y - 0.04).abs() < 1e-12);
        assert!((b2.sum_xy - 0.08).abs() < 1e-12);
        assert!((b2.sum_stall - 0.002).abs() < 1e-12);
        // prefill bucketed at 64-token granularity
        assert_eq!(r0.prefill[0].key, 100 / PREFILL_BUCKET_TOKENS);
        assert_eq!(art.observed_rungs(), vec![0]);
    }

    #[test]
    fn artifact_roundtrips_through_json() {
        let mut art = CalibrationArtifact::new("qwen", "bursty", 7, 2, 4, "engine-synthetic", 3);
        for i in 0..50 {
            let occ = 1.0 + (i % 4) as f64;
            art.record(&sample(false, i % 3, occ, 0.001 * occ + 0.0003, 1e-4));
            art.record(&sample(true, i % 3, 64.0 * occ, 0.01 * occ, 0.0));
        }
        let re = CalibrationArtifact::from_json(&art.to_json()).unwrap();
        assert_eq!(art, re);

        let dir = std::env::temp_dir().join("lexi_calibration_artifact_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = artifact_path(&dir, "qwen", "bursty");
        art.save(&path).unwrap();
        assert_eq!(CalibrationArtifact::load(&path).unwrap(), art);
    }

    #[test]
    fn ensure_matches_gates_model_and_slots_only() {
        use crate::config::server::{ScenarioKind, ServerConfig};
        let art = CalibrationArtifact::new("qwen", "poisson", 7, 2, 4, "engine-synthetic", 1);
        let cfg = ServerConfig {
            replicas: 2,
            slots_per_replica: 4,
            seed: 7,
            scenario: ScenarioKind::Poisson,
            ..Default::default()
        };
        assert!(art.ensure_matches("qwen", &cfg).is_ok());
        assert!(art.ensure_matches("olmoe", &cfg).is_err());
        let mut wrong_slots = cfg.clone();
        wrong_slots.slots_per_replica = 8;
        assert!(art.ensure_matches("qwen", &wrong_slots).is_err());
        // scenario/seed transfer is allowed (notice only)
        let mut transfer = cfg;
        transfer.scenario = ScenarioKind::Bursty;
        transfer.seed = 11;
        assert!(art.ensure_matches("qwen", &transfer).is_ok());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let art = CalibrationArtifact::new("m", "s", 0, 1, 1, "engine-synthetic", 1);
        let mut v = art.to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("version".into(), Json::Num(99.0));
        }
        assert!(CalibrationArtifact::from_json(&v).is_err());
    }
}
