//! Least-squares fitting of the sim [`ServiceModel`] from calibration
//! observations.
//!
//! The service model is linear in exactly the regressors the recorder
//! tags: prefill time `= overhead + per_token * prompt_tokens`, decode
//! step time `= base + per_slot * occupancy`. Each term is fit per rung
//! by weighted least squares over the artifact's buckets — which, since
//! buckets keep full second-moment sums, equals the ordinary least
//! squares over the raw samples. Simulated residency stall is fitted as
//! a separate per-step mean (it is virtual time the sim replica's own
//! residency model normally reproduces; `include_stall` folds it into
//! the service terms for consumers that run without one).

use anyhow::{Context, Result};

use crate::server::ladder::QualityLadder;
use crate::server::replica::ServiceModel;

use super::observe::{CalibrationArtifact, RungSamples, SampleBucket};

/// Floor for fitted step times: a zero-cost phase would collapse the
/// event loop into zero-width instants.
const MIN_STEP_S: f64 = 1e-9;

/// One fitted linear term `y = base_s + per_x_s * x`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearTerm {
    pub base_s: f64,
    pub per_x_s: f64,
    /// Samples the fit was computed from.
    pub n: u64,
}

impl LinearTerm {
    pub fn at(&self, x: f64) -> f64 {
        self.base_s + self.per_x_s * x
    }
}

/// Fitted service terms of one quality-ladder rung.
#[derive(Clone, Debug, PartialEq)]
pub struct RungFit {
    /// `None` when the rung has no samples of that phase kind.
    pub prefill: Option<LinearTerm>,
    pub decode: Option<LinearTerm>,
    /// Mean simulated residency stall per step, by phase kind (0 when
    /// the run carried no HBM budget).
    pub prefill_stall_s: f64,
    pub decode_stall_s: f64,
}

impl RungFit {
    /// Calibrated `(prefill_overhead_s, prefill_s_per_token)` — the one
    /// place the stall fold and non-negativity clamps live.
    pub fn prefill_terms(&self, include_stall: bool) -> Option<(f64, f64)> {
        self.prefill.map(|pf| {
            let stall = if include_stall { self.prefill_stall_s } else { 0.0 };
            ((pf.base_s + stall).max(0.0), pf.per_x_s.max(0.0))
        })
    }

    /// Calibrated per-occupancy decode table (`decode_step_s`).
    pub fn decode_table(&self, slots: usize, include_stall: bool) -> Option<Vec<f64>> {
        self.decode.map(|df| {
            let stall = if include_stall { self.decode_stall_s } else { 0.0 };
            (1..=slots)
                .map(|occ| (df.at(occ as f64) + stall).max(MIN_STEP_S))
                .collect()
        })
    }
}

/// Weighted least squares over bucket sufficient statistics. Falls back
/// to a through-origin fit when the regressor is (near-)constant, and
/// clamps both coefficients non-negative: a negatively-sloped or
/// negatively-based service model is measurement noise, not physics.
fn wls(buckets: &[SampleBucket]) -> Option<LinearTerm> {
    let n: f64 = buckets.iter().map(|b| b.n as f64).sum();
    if n <= 0.0 {
        return None;
    }
    let sx: f64 = buckets.iter().map(|b| b.sum_x).sum();
    let sy: f64 = buckets.iter().map(|b| b.sum_y).sum();
    let sxx: f64 = buckets.iter().map(|b| b.sum_x2).sum();
    let sxy: f64 = buckets.iter().map(|b| b.sum_xy).sum();
    let det = n * sxx - sx * sx;
    // least-squares slope of y = b*x with no intercept (equals sy/sx
    // when only one distinct x was observed)
    let origin_slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let (mut base, mut slope) = if det > 1e-12 * n * sxx.max(1.0) {
        let slope = (n * sxy - sx * sy) / det;
        ((sy - slope * sx) / n, slope)
    } else if sx > 0.0 {
        // one distinct x: scale through the origin
        (0.0, origin_slope)
    } else {
        (sy / n, 0.0)
    };
    if slope < 0.0 {
        slope = 0.0;
        base = sy / n;
    }
    if base < 0.0 {
        base = 0.0;
        slope = origin_slope.max(0.0);
    }
    Some(LinearTerm {
        base_s: base,
        per_x_s: slope,
        n: n as u64,
    })
}

fn mean_stall(buckets: &[SampleBucket]) -> f64 {
    let n: u64 = buckets.iter().map(|b| b.n).sum();
    if n == 0 {
        return 0.0;
    }
    buckets.iter().map(|b| b.sum_stall).sum::<f64>() / n as f64
}

/// Fit both service terms of one rung's observations.
pub fn fit_rung(rs: &RungSamples) -> RungFit {
    RungFit {
        prefill: wls(&rs.prefill),
        decode: wls(&rs.decode),
        prefill_stall_s: mean_stall(&rs.prefill),
        decode_stall_s: mean_stall(&rs.decode),
    }
}

impl ServiceModel {
    /// Service model of one rung fitted from measured engine step times.
    /// Requires both phase kinds observed for the rung; use
    /// [`apply_to_ladder`] for partial, best-effort recalibration.
    /// `include_stall` folds the fitted mean residency stall into the
    /// terms — leave it off when the consuming sim replica carries its
    /// own residency model (the stall would be double-counted).
    pub fn from_calibration(
        art: &CalibrationArtifact,
        rung: usize,
        slots: usize,
        include_stall: bool,
    ) -> Result<ServiceModel> {
        anyhow::ensure!(slots >= 1, "service model needs at least one slot");
        let rs = art
            .rungs
            .get(rung)
            .with_context(|| format!("artifact has no rung {rung}"))?;
        let fit = fit_rung(rs);
        let (prefill_overhead_s, prefill_s_per_token) = fit
            .prefill_terms(include_stall)
            .with_context(|| format!("rung {rung} has no prefill samples"))?;
        let decode_step_s = fit
            .decode_table(slots, include_stall)
            .with_context(|| format!("rung {rung} has no decode samples"))?;
        Ok(ServiceModel {
            label: format!("{}-cal-r{rung}", art.model),
            prefill_overhead_s,
            prefill_s_per_token,
            decode_step_s,
        })
    }
}

/// Replace every ladder rung's analytical service terms with fitted ones
/// where the artifact has observations; rungs (or phase kinds) the
/// engine run never exercised keep their analytical values. Returns the
/// rung indices that were (at least partially) recalibrated.
pub fn apply_to_ladder(
    ladder: &mut QualityLadder,
    art: &CalibrationArtifact,
    include_stall: bool,
) -> Vec<usize> {
    let mut applied = Vec::new();
    for (j, rung) in ladder.points_mut().iter_mut().enumerate() {
        let Some(rs) = art.rungs.get(j) else { continue };
        let fit = fit_rung(rs);
        let slots = rung.service.slots();
        let mut svc = rung.service.clone();
        let mut touched = false;
        if let Some((overhead, per_token)) = fit.prefill_terms(include_stall) {
            svc.prefill_overhead_s = overhead;
            svc.prefill_s_per_token = per_token;
            touched = true;
        }
        if let Some(table) = fit.decode_table(slots, include_stall) {
            svc.decode_step_s = table;
            touched = true;
        }
        if touched {
            svc.label = format!("{}+cal", svc.label);
            rung.service = svc;
            applied.push(j);
        }
    }
    applied
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::StepSample;

    fn artifact_from(samples: &[StepSample], n_rungs: usize) -> CalibrationArtifact {
        let mut art = CalibrationArtifact::new("m", "s", 0, 1, 4, "engine-synthetic", n_rungs);
        art.record_all(samples.iter());
        art
    }

    fn decode(rung: usize, occ: f64, dt: f64) -> StepSample {
        StepSample {
            prefill: false,
            rung,
            x: occ,
            dt_s: dt,
            stall_s: 0.0,
        }
    }

    fn prefill(rung: usize, tokens: f64, dt: f64) -> StepSample {
        StepSample {
            prefill: true,
            rung,
            x: tokens,
            dt_s: dt,
            stall_s: 0.0,
        }
    }

    #[test]
    fn fitter_recovers_known_coefficients() {
        // decode: dt = 0.002 + 0.0005 * occ; prefill: dt = 0.001 + 1e-5 * tokens
        let mut samples = Vec::new();
        for _ in 0..3 {
            for occ in 1..=4 {
                samples.push(decode(0, occ as f64, 0.002 + 0.0005 * occ as f64));
            }
            for tokens in [64.0, 128.0, 256.0] {
                samples.push(prefill(0, tokens, 0.001 + 1e-5 * tokens));
            }
        }
        let art = artifact_from(&samples, 1);
        let fit = fit_rung(&art.rungs[0]);
        let df = fit.decode.unwrap();
        assert!((df.base_s - 0.002).abs() < 1e-9, "decode base {}", df.base_s);
        assert!((df.per_x_s - 0.0005).abs() < 1e-9);
        assert_eq!(df.n, 12);
        let pf = fit.prefill.unwrap();
        assert!((pf.base_s - 0.001).abs() < 1e-9);
        assert!((pf.per_x_s - 1e-5).abs() < 1e-12);

        let svc = ServiceModel::from_calibration(&art, 0, 4, false).unwrap();
        assert_eq!(svc.slots(), 4);
        assert!((svc.step_time(3) - 0.0035).abs() < 1e-9);
        assert!((svc.prefill_time(100) - 0.002).abs() < 1e-9);
    }

    #[test]
    fn stall_is_fitted_separately_and_optionally_included() {
        let mut samples = Vec::new();
        for occ in 1..=4 {
            let mut s = decode(0, occ as f64, 0.002 + 0.0005 * occ as f64);
            s.stall_s = 0.01; // constant simulated stall per step
            samples.push(s);
            samples.push(prefill(0, 64.0 * occ as f64, 1e-5 * 64.0 * occ as f64));
        }
        let art = artifact_from(&samples, 1);
        let fit = fit_rung(&art.rungs[0]);
        // compute fit unaffected by the stall column
        assert!((fit.decode.unwrap().base_s - 0.002).abs() < 1e-9);
        assert!((fit.decode_stall_s - 0.01).abs() < 1e-12);
        assert_eq!(fit.prefill_stall_s, 0.0);

        let lean = ServiceModel::from_calibration(&art, 0, 4, false).unwrap();
        let full = ServiceModel::from_calibration(&art, 0, 4, true).unwrap();
        assert!((full.step_time(2) - lean.step_time(2) - 0.01).abs() < 1e-9);
    }

    #[test]
    fn degenerate_single_occupancy_scales_through_origin() {
        let samples: Vec<StepSample> = (0..8).map(|_| decode(0, 4.0, 0.02)).collect();
        let art = artifact_from(&samples, 1);
        let df = fit_rung(&art.rungs[0]).decode.unwrap();
        assert_eq!(df.base_s, 0.0);
        assert!((df.per_x_s - 0.005).abs() < 1e-12);
    }

    #[test]
    fn negative_slopes_are_clamped_to_the_mean() {
        // dt DECREASES with occupancy (noise): fall back to a flat mean
        let samples = vec![decode(0, 1.0, 0.03), decode(0, 4.0, 0.01)];
        let art = artifact_from(&samples, 1);
        let df = fit_rung(&art.rungs[0]).decode.unwrap();
        assert_eq!(df.per_x_s, 0.0);
        assert!((df.base_s - 0.02).abs() < 1e-12);
    }

    #[test]
    fn missing_phase_or_rung_errors_in_strict_mode() {
        let art = artifact_from(&[decode(0, 2.0, 0.01)], 2);
        assert!(ServiceModel::from_calibration(&art, 0, 4, false).is_err()); // no prefill
        assert!(ServiceModel::from_calibration(&art, 1, 4, false).is_err()); // empty rung
        assert!(ServiceModel::from_calibration(&art, 9, 4, false).is_err()); // out of range
    }

    #[test]
    fn apply_to_ladder_recalibrates_observed_rungs_only() {
        use crate::moe::allocation::Allocation;
        let base = ServiceModel::synthetic("base", 1e-4, 0.01, 4);
        let mut ladder = QualityLadder::from_points_1d(
            (0..2)
                .map(|i| {
                    crate::server::ladder::QualityPoint::k_only(
                        &format!("r{i}"),
                        Allocation::uniform(4, 2),
                        base.clone(),
                        i as f64,
                    )
                })
                .collect(),
        );
        let mut samples = Vec::new();
        for occ in 1..=4 {
            samples.push(decode(0, occ as f64, 0.1 + 0.01 * occ as f64));
        }
        let art = artifact_from(&samples, 2);
        let applied = apply_to_ladder(&mut ladder, &art, false);
        assert_eq!(applied, vec![0]);
        // rung 0: decode recalibrated, prefill (unobserved) retained
        let cal0 = &ladder.points()[0].service;
        assert!((cal0.step_time(2) - 0.12).abs() < 1e-9);
        assert!((cal0.prefill_time(100) - base.prefill_time(100)).abs() < 1e-12);
        assert!(cal0.label.ends_with("+cal"));
        // rung 1 untouched
        assert_eq!(ladder.points()[1].service.step_time(2), 0.01);
    }
}
