//! Calibration subsystem: fit the sim [`ServiceModel`] from engine
//! step-time telemetry, and cross-validate the two replica backends.
//!
//! The virtual-time sim replica takes its phase durations from an
//! analytical service model; the engine-backed replica measures real
//! wall-clock steps. Until those two agree on latency *distributions*,
//! sim-side throughput/SLO results are only as trustworthy as the
//! analytical guess. This module closes the loop with the same
//! measure-then-model discipline LExI applies to per-layer sensitivity:
//!
//! 1. **Observe** ([`observe`]) — the engine backend tags every measured
//!    step with phase kind, quality-ladder rung, occupancy regressor,
//!    and (separately) simulated residency stall
//!    ([`StepSample`](crate::server::StepSample)); samples are bucketed
//!    into a [`CalibrationArtifact`] that keeps full second-moment sums,
//!    so fitting from the artifact equals fitting from the raw stream.
//! 2. **Fit** ([`fit`]) — weighted least squares recovers each rung's
//!    `prefill = overhead + per_token·tokens` and `decode = base +
//!    per_slot·occupancy` terms
//!    ([`ServiceModel::from_calibration`](crate::server::ServiceModel::from_calibration)),
//!    plus a separate mean stall term when an HBM budget was active;
//!    [`apply_to_ladder`] refits a [`QualityLadder`] in place, leaving
//!    unobserved rungs analytical.
//! 3. **Cross-validate** ([`validate`]) — `lexi cross-validate` replays
//!    one seeded trace on the engine and on the sim twice (raw and
//!    calibrated) and gates on per-percentile TTFT/TPOT divergence and
//!    exact served-token parity. CI runs the gate on a fixed seed; the
//!    artifact it uploads is the trust anchor later sim-side results
//!    cite (`lexi bench-serve --calibration <artifact>`).
//!
//! [`ServiceModel`]: crate::server::ServiceModel
//! [`QualityLadder`]: crate::server::QualityLadder

pub mod fit;
pub mod observe;
pub mod validate;

pub use fit::{apply_to_ladder, fit_rung, LinearTerm, RungFit};
pub use observe::{artifact_path, CalibrationArtifact, RungSamples, SampleBucket};
pub use validate::{
    calibrate, cross_validate, BackendSummary, ContenderValidation, CrossValidation, Divergence,
    DEFAULT_TOLERANCE, PERCENTILES,
};
