//! Per-layer load-balance summaries feeding the MoE kernel time model.

use crate::moe::routing::{LoadStats, RoutingSim};
use crate::util::Pcg32;

/// Per-layer routing environment: one popularity distribution per layer,
/// seeded deterministically so every figure run sees the same "model".
pub struct LayerRouting {
    pub sims: Vec<RoutingSim>,
}

impl LayerRouting {
    /// Synthetic trained-router popularity: skew varies smoothly with
    /// depth (mid layers route more uniformly — matching the observation
    /// that expert specialization concentrates near the ends).
    pub fn synthetic(n_layers: usize, n_experts: usize, seed: u64) -> Self {
        let mut sims = Vec::with_capacity(n_layers);
        for j in 0..n_layers {
            let x = j as f64 / (n_layers.max(2) - 1) as f64;
            let spread = 0.4 + 0.8 * (2.0 * (x - 0.5)).powi(2); // U-shape
            let mut rng = Pcg32::new(seed, 1000 + j as u64);
            sims.push(RoutingSim::new(n_experts, spread, &mut rng));
        }
        LayerRouting { sims }
    }

    /// From measured calibration frequencies ([L][E], the analogue's
    /// router statistics exported by the build step).
    pub fn from_calibration(freq: &[Vec<f32>]) -> Self {
        LayerRouting {
            sims: freq.iter().map(|f| RoutingSim::from_frequencies(f)).collect(),
        }
    }

    /// Inter-pruning applied per layer: keep the top (1-frac) experts by
    /// popularity (the calibration-importance ranking NAEE uses).
    pub fn pruned(&self, frac: f64) -> Self {
        let sims = self
            .sims
            .iter()
            .map(|sim| {
                let e = sim.n_experts();
                let remove = (e as f64 * frac).round() as usize;
                // shared popularity ranking (RoutingSim::by_popularity):
                // drop the tail of the descending order
                let order = sim.by_popularity();
                let mut keep = vec![true; e];
                for &i in order.iter().rev().take(remove.min(e - 1)) {
                    keep[i] = false;
                }
                sim.pruned(&keep)
            })
            .collect();
        LayerRouting { sims }
    }

    /// Load stats for layer `j` with `tokens` tokens and top-`k`.
    pub fn stats(&self, j: usize, tokens: usize, k: usize, trials: usize, seed: u64) -> LoadStats {
        let kept = self.sims[j]
            .popularity
            .iter()
            .filter(|&&p| p > 0.0)
            .count();
        self.sims[j].stats_capped(tokens, k.min(kept), trials, seed)
    }

    /// Probability that the 2nd-ranked gate weight falls below
    /// `threshold` x the 1st — the NAEE dynamic-skip trigger rate.
    /// Estimated by sampling token gate vectors from the layer popularity.
    pub fn skip_probability(&self, j: usize, threshold: f64, trials: usize, seed: u64) -> f64 {
        let sim = &self.sims[j];
        let mut rng = Pcg32::seeded(seed ^ 0x517b_ab1e);
        let mut skipped = 0usize;
        for _ in 0..trials {
            // token gate logits: log popularity + Gumbel-ish noise
            let mut best = (f64::NEG_INFINITY, f64::NEG_INFINITY);
            for &p in &sim.popularity {
                if p <= 0.0 {
                    continue;
                }
                let w = p.ln() + rng.gen_normal();
                if w > best.0 {
                    best = (w, best.0);
                } else if w > best.1 {
                    best.1 = w;
                }
            }
            let (w1, w2) = (best.0.exp(), best.1.exp());
            let (g1, g2) = (w1 / (w1 + w2), w2 / (w1 + w2));
            if g2 < threshold * g1 {
                skipped += 1;
            }
        }
        skipped as f64 / trials as f64
    }
}

impl RoutingSim {
    fn stats_capped(&self, tokens: usize, k: usize, trials: usize, seed: u64) -> LoadStats {
        self.load_stats(tokens, k.max(1), trials, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_layers_differ() {
        let lr = LayerRouting::synthetic(8, 16, 3);
        assert_eq!(lr.sims.len(), 8);
        assert_ne!(lr.sims[0].popularity, lr.sims[4].popularity);
    }

    #[test]
    fn pruning_removes_lowest_popularity() {
        let lr = LayerRouting::synthetic(2, 8, 5);
        let pruned = lr.pruned(0.25);
        for (orig, after) in lr.sims.iter().zip(&pruned.sims) {
            let removed: Vec<usize> = (0..8)
                .filter(|&i| after.popularity[i] == 0.0)
                .collect();
            assert_eq!(removed.len(), 2);
            // removed ones were the least popular
            let min_kept = (0..8)
                .filter(|&i| after.popularity[i] > 0.0)
                .map(|i| orig.popularity[i])
                .fold(f64::INFINITY, f64::min);
            for i in removed {
                assert!(orig.popularity[i] <= min_kept + 1e-12);
            }
        }
    }

    #[test]
    fn skip_probability_monotone_in_threshold() {
        let lr = LayerRouting::synthetic(1, 8, 7);
        let lo = lr.skip_probability(0, 0.1, 400, 1);
        let hi = lr.skip_probability(0, 0.9, 400, 1);
        assert!(hi >= lo);
        assert!((0.0..=1.0).contains(&lo) && (0.0..=1.0).contains(&hi));
    }
}
