//! Roofline primitives: kernel time = max(compute, memory) + overhead.

use super::hardware::Hardware;

/// Time of a dense GEMM C[m,n] += A[m,k] B[k,n].
pub fn gemm_time(hw: &Hardware, m: usize, n: usize, k: usize) -> f64 {
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let bytes = ((m * k + k * n + m * n) * hw.dtype_bytes) as f64;
    (flops / hw.eff_flops()).max(bytes / hw.eff_bw()) + hw.kernel_overhead
}

/// Time of a streaming elementwise/reduction pass over `bytes` bytes.
pub fn stream_time(hw: &Hardware, bytes: f64) -> f64 {
    bytes / hw.eff_bw() + hw.kernel_overhead
}

/// Makespan of scheduling independent tile jobs onto `lanes` parallel
/// lanes (LPT greedy). `tiles` holds per-job tile counts; each tile takes
/// `tile_time`. This models the fused-MoE kernel executing per-expert
/// GEMM tiles across SM groups: imbalanced loads leave lanes idle.
pub fn lpt_makespan(tiles: &[u64], lanes: usize, tile_time: f64) -> f64 {
    assert!(lanes > 0);
    let mut jobs: Vec<u64> = tiles.iter().copied().filter(|&t| t > 0).collect();
    jobs.sort_unstable_by(|a, b| b.cmp(a));
    let mut lane_load = vec![0u64; lanes];
    for j in jobs {
        // assign to least-loaded lane
        let idx = lane_load
            .iter()
            .enumerate()
            .min_by_key(|(_, &l)| l)
            .map(|(i, _)| i)
            .unwrap();
        lane_load[idx] += j;
    }
    *lane_load.iter().max().unwrap() as f64 * tile_time
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_compute_bound_for_large() {
        let hw = Hardware::h100();
        let t = gemm_time(&hw, 4096, 4096, 4096);
        let flops = 2.0 * 4096f64.powi(3);
        assert!((t - hw.kernel_overhead - flops / hw.eff_flops()).abs() / t < 1e-6);
    }

    #[test]
    fn gemm_memory_bound_for_skinny() {
        let hw = Hardware::h100();
        // decode-like: 16 x 14336 x 4096 — weight reading dominates
        let t = gemm_time(&hw, 16, 14336, 4096);
        let bytes = ((16 * 4096 + 4096 * 14336 + 16 * 14336) * 2) as f64;
        assert!((t - hw.kernel_overhead - bytes / hw.eff_bw()).abs() / t < 1e-6);
    }

    #[test]
    fn lpt_perfectly_balanced() {
        // 8 jobs of 4 tiles on 4 lanes -> 8 tiles makespan
        let m = lpt_makespan(&[4; 8], 4, 1.0);
        assert_eq!(m, 8.0);
    }

    #[test]
    fn lpt_imbalance_dominates() {
        // one giant job pins the makespan regardless of lanes
        let m = lpt_makespan(&[100, 1, 1, 1], 4, 1.0);
        assert_eq!(m, 100.0);
    }

    #[test]
    fn lpt_ignores_empty_jobs() {
        assert_eq!(lpt_makespan(&[0, 0, 5], 2, 1.0), 5.0);
    }
}
