//! Inter-GPU communication model: tensor-parallel all-reduce and the MoE
//! dispatch/combine traffic (the "all-reduce and broadcast volume grows
//! with active experts" cost the paper cites in §1).

use super::hardware::Hardware;

/// Ring all-reduce of `bytes` across `n_gpus`: 2(G-1)/G traffic factor.
pub fn allreduce_time(hw: &Hardware, bytes: f64, n_gpus: usize) -> f64 {
    if n_gpus <= 1 {
        return 0.0;
    }
    let g = n_gpus as f64;
    let wire = bytes * 2.0 * (g - 1.0) / g / hw.nvlink_bw;
    wire + hw.allreduce_latency
}

/// MoE dispatch + combine: routing `tokens` activations of width `hidden`
/// to `k` experts and gathering the weighted results back. On the TP
/// deployment this is HBM traffic (scatter/gather through the fused
/// kernel); volume scales with k — LExI's communication lever.
pub fn dispatch_combine_bytes(hw: &Hardware, tokens: usize, hidden: usize, k: f64) -> f64 {
    2.0 * tokens as f64 * k * hidden as f64 * hw.dtype_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_zero_for_single_gpu() {
        let hw = Hardware::h100();
        assert_eq!(allreduce_time(&hw, 1e9, 1), 0.0);
        assert!(allreduce_time(&hw, 1e9, 4) > 0.0);
    }

    #[test]
    fn allreduce_grows_with_gpus() {
        let hw = Hardware::h100();
        // traffic factor 2(G-1)/G increases in G
        assert!(allreduce_time(&hw, 1e9, 8) > allreduce_time(&hw, 1e9, 2));
    }

    #[test]
    fn dispatch_scales_with_k() {
        let hw = Hardware::h100();
        let b1 = dispatch_combine_bytes(&hw, 1024, 4096, 2.0);
        let b2 = dispatch_combine_bytes(&hw, 1024, 4096, 4.0);
        assert!((b2 / b1 - 2.0).abs() < 1e-12);
    }
}
