//! Analytical H100 performance model (DESIGN.md §3 substitution).
//!
//! Replaces the paper's 4x/2x H100 + vLLM testbed. It reproduces the
//! first-order mechanisms the paper's throughput numbers are made of:
//!
//! 1. compute ∝ sum_j k_j (expert GEMM FLOPs)            — LExI's lever
//! 2. grouped-GEMM tile quantization + load imbalance    — why pruning
//!    does not translate into speedups (Fig. 2)
//! 3. decode is HBM-bandwidth-bound on (active) expert weights
//! 4. tensor-parallel all-reduce + dispatch/combine traffic
//!
//! Absolute tok/s differ from the paper's testbed; the *shape* (who wins,
//! crossovers) is what the figure harness asserts.

pub mod comm;
pub mod hardware;
pub mod loadbalance;
pub mod model;
pub mod roofline;

pub use hardware::Hardware;
pub use model::{PerfBreakdown, PerfModel};
