//! Accelerator constants (NVIDIA H100 SXM5, the paper's testbed).

#[derive(Clone, Copy, Debug)]
pub struct Hardware {
    /// Peak dense BF16 tensor-core throughput (FLOP/s).
    pub peak_flops: f64,
    /// HBM3 bandwidth (B/s).
    pub hbm_bw: f64,
    /// NVLink per-direction bandwidth per GPU (B/s).
    pub nvlink_bw: f64,
    /// Achievable fraction of peak FLOPs for large GEMMs.
    pub gemm_eff: f64,
    /// Achievable fraction of HBM bandwidth for streaming reads.
    pub mem_eff: f64,
    /// Fixed kernel-launch / scheduling overhead per fused kernel (s).
    pub kernel_overhead: f64,
    /// Collective latency per all-reduce (s).
    pub allreduce_latency: f64,
    /// Row-tile granularity of the grouped expert GEMM (tokens): each
    /// active expert's token group is padded to a multiple of this.
    pub moe_tile_rows: usize,
    /// Parallel execution lanes for independent expert GEMM tiles
    /// (SM groups available to the fused MoE kernel).
    pub sm_lanes: usize,
    /// Weight dtype bytes (BF16).
    pub dtype_bytes: usize,
    /// Sustained host→HBM link bandwidth (B/s; PCIe Gen5 x16 effective)
    /// — the cost of a non-resident expert under an HBM budget.
    pub host_link_bw: f64,
    /// Fixed per-transfer host→HBM issue latency (s).
    pub host_link_latency: f64,
}

impl Default for Hardware {
    fn default() -> Self {
        Hardware::h100()
    }
}

impl Hardware {
    pub fn h100() -> Self {
        Hardware {
            peak_flops: 989e12,
            hbm_bw: 3.35e12,
            nvlink_bw: 450e9,
            gemm_eff: 0.65,
            mem_eff: 0.80,
            kernel_overhead: 5e-6,
            allreduce_latency: 12e-6,
            moe_tile_rows: 64,
            sm_lanes: 32,
            dtype_bytes: 2,
            host_link_bw: 5.5e10,
            host_link_latency: 1e-5,
        }
    }

    /// Previous-generation tier (NVIDIA A100 SXM4 80GB) for
    /// heterogeneous-cluster sweeps: ~1/3 the BF16 tensor throughput,
    /// HBM2e instead of HBM3, NVLink3, PCIe Gen4 host link. Kernel
    /// overheads and tile geometry are kept identical so perf-model
    /// deltas isolate the bandwidth/compute gap.
    pub fn a100() -> Self {
        Hardware {
            peak_flops: 312e12,
            hbm_bw: 2.04e12,
            nvlink_bw: 300e9,
            gemm_eff: 0.65,
            mem_eff: 0.80,
            kernel_overhead: 5e-6,
            allreduce_latency: 12e-6,
            moe_tile_rows: 64,
            sm_lanes: 32,
            dtype_bytes: 2,
            host_link_bw: 2.5e10,
            host_link_latency: 1e-5,
        }
    }

    /// Effective compute rate (FLOP/s) after GEMM efficiency.
    pub fn eff_flops(&self) -> f64 {
        self.peak_flops * self.gemm_eff
    }

    /// Effective memory bandwidth (B/s).
    pub fn eff_bw(&self) -> f64 {
        self.hbm_bw * self.mem_eff
    }
}
