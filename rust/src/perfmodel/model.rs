//! End-to-end throughput model for one Table-1 model under any transform.

use crate::config::model::ModelSpec;
use crate::moe::arch::{LayerGeom, ModelGeom};
use crate::moe::transform::Transform;
use crate::util::Pcg32;

use super::comm::{allreduce_time, dispatch_combine_bytes};
use super::hardware::Hardware;
use super::loadbalance::LayerRouting;
use super::roofline::{gemm_time, lpt_makespan, stream_time};

/// Cap on simulated tokens in the routing Monte-Carlo; larger batches are
/// scaled proportionally (relative load shape is preserved, cost is not).
const SIM_TOKEN_CAP: usize = 2048;

#[derive(Clone, Copy, Debug, Default)]
pub struct PerfBreakdown {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub total_s: f64,
    /// Paper metric: (input + output tokens) * batch / end-to-end time.
    pub throughput_tok_s: f64,
    pub attn_s: f64,
    pub moe_s: f64,
    pub comm_s: f64,
    /// Host→HBM expert weight traffic under an HBM budget (0 without
    /// one; included in `moe_s`). The residency subsystem's analytical
    /// twin — see [`PerfModel::with_hbm_budget_bytes`].
    pub expert_fetch_s: f64,
    /// Mean over layers of the expected max/mean expert-load ratio.
    pub mean_imbalance: f64,
}

/// Performance model instance for one model at paper scale.
pub struct PerfModel {
    pub hw: Hardware,
    pub spec: ModelSpec,
    pub routing: LayerRouting,
    pub trials: usize,
    pub seed: u64,
    /// Per-GPU HBM bytes available for expert weights. `None` (the
    /// default) models the historical assumption: every expert resident
    /// at zero cost. `Some` adds the expert-traffic term — non-resident
    /// active experts stream over the host link.
    pub hbm_expert_budget_bytes: Option<f64>,
}

impl PerfModel {
    pub fn new(spec: ModelSpec, seed: u64) -> Self {
        let routing = LayerRouting::synthetic(spec.n_layers, spec.n_experts, seed);
        PerfModel {
            hw: Hardware::h100(),
            spec,
            routing,
            trials: 4,
            seed,
            hbm_expert_budget_bytes: None,
        }
    }

    /// Use measured analogue router frequencies instead of the synthetic
    /// popularity (freq[l][e] from artifacts/<model>/calib.npz).
    pub fn with_calibration(mut self, freq: &[Vec<f32>]) -> Self {
        self.routing = LayerRouting::from_calibration(freq);
        self
    }

    /// Constrain expert weights to a per-GPU HBM budget: each layer gets
    /// an even share, the most-popular experts that fit are resident
    /// (the k_vec-aware pinning the residency subsystem implements), and
    /// the uncovered routing mass streams over the host link. This is
    /// the term that lets Stage-2 allocation search trade active experts
    /// against weight traffic instead of FLOPs alone.
    pub fn with_hbm_budget_bytes(mut self, bytes: f64) -> Self {
        self.hbm_expert_budget_bytes = Some(bytes);
        self
    }

    /// Fraction of layer `j`'s routed mass NOT covered by the experts
    /// resident under the budget (0 without a budget), plus the expected
    /// number of active-but-non-resident experts given `active` distinct
    /// active experts.
    fn residency_miss(
        &self,
        geom: &LayerGeom,
        routing: &LayerRouting,
        j: usize,
        active: f64,
    ) -> f64 {
        let Some(budget) = self.hbm_expert_budget_bytes else {
            return 0.0;
        };
        let g = self.spec.paper.n_gpus as f64;
        let shard = geom.expert_weight_bytes(self.hw.dtype_bytes) / g;
        let per_layer = budget / self.spec.n_layers as f64;
        let resident = (per_layer / shard).floor() as usize;
        if resident >= geom.n_experts {
            return 0.0; // everything fits: exactly the historical model
        }
        let miss_mass = (1.0 - routing.sims[j].top_p_mass(resident)).max(0.0);
        if miss_mass < 1e-12 {
            return 0.0;
        }
        // expected non-resident active experts ~ active weighted by the
        // uncovered mass (popular experts are both the most likely to be
        // active and the ones pinned resident)
        miss_mass * active
    }

    /// Host-link streaming time for `miss_experts` expert shards.
    fn host_fetch_time(&self, geom: &LayerGeom, miss_experts: f64) -> f64 {
        if miss_experts <= 0.0 {
            return 0.0;
        }
        let g = self.spec.paper.n_gpus as f64;
        let bytes = miss_experts * geom.expert_weight_bytes(self.hw.dtype_bytes) / g;
        self.hw.host_link_latency + bytes / self.hw.host_link_bw
    }

    fn geom(&self, t: &Transform) -> ModelGeom {
        let mut g = ModelGeom::paper_scale(&self.spec);
        g.layer = LayerGeom {
            ffn: t.ffn_dim(g.layer.ffn),
            n_experts: t.experts_kept(&self.spec),
            ..g.layer
        };
        g
    }

    fn routing_for(&self, t: &Transform) -> LayerRouting {
        match t {
            Transform::InterPrune { frac } | Transform::LexiPlusInter { frac, .. } => {
                self.routing.pruned(*frac)
            }
            _ => LayerRouting {
                sims: self.routing.sims.clone(),
            },
        }
    }

    /// Per-layer expected active k under the transform (DynamicSkip is
    /// token-adaptive, so its k is fractional in expectation).
    fn k_eff(&self, t: &Transform, routing: &LayerRouting) -> Vec<f64> {
        match t {
            Transform::DynamicSkip { threshold } => (0..self.spec.n_layers)
                .map(|j| {
                    let p = routing.skip_probability(j, *threshold, 256, self.seed + j as u64);
                    (self.spec.top_k as f64 - p).max(1.0)
                })
                .collect(),
            // allocation + skipping: only layers allocated k >= 2 have a
            // 2nd expert to drop, and each sheds its layer's expected
            // skip mass
            Transform::LexiPlusSkip { allocation, threshold } => allocation
                .k
                .iter()
                .enumerate()
                .map(|(j, &k)| {
                    if k >= 2 {
                        let p = routing.skip_probability(j, *threshold, 256, self.seed + j as u64);
                        (k as f64 - p).max(1.0)
                    } else {
                        k as f64
                    }
                })
                .collect(),
            _ => t
                .k_per_layer(&self.spec)
                .iter()
                .map(|&k| k as f64)
                .collect(),
        }
    }

    /// One layer's prefill time over `tokens` tokens at context `ctx`.
    #[allow(clippy::too_many_arguments)]
    fn layer_prefill(
        &self,
        geom: &LayerGeom,
        routing: &LayerRouting,
        j: usize,
        tokens: usize,
        ctx: usize,
        k: f64,
        imbalance_out: &mut f64,
    ) -> (f64, f64, f64, f64) {
        let hw = &self.hw;
        let g = self.spec.paper.n_gpus;
        let h = geom.hidden;

        // Attention: QKVO projections (sharded over heads) + score/value.
        let attn = gemm_time(hw, tokens, 4 * h / g, h)
            + gemm_time(hw, tokens, ctx, h / g)
            + gemm_time(hw, tokens, h / g, ctx);

        // Router GEMM.
        let router = gemm_time(hw, tokens, geom.n_experts, h);

        // Fused expert GEMMs: Monte-Carlo per-expert loads -> tile counts
        // -> LPT makespan over SM lanes; memory floor = streaming every
        // active expert's (sharded) weights once.
        let sim_tokens = tokens.min(SIM_TOKEN_CAP);
        let scale = tokens as f64 / sim_tokens as f64;
        let mut rng = Pcg32::new(self.seed, 777 + j as u64);
        let k_int = (k.ceil() as usize).max(1);
        let loads = routing.sims[j].sample_loads(sim_tokens, k_int.min(geom.n_experts), &mut rng);
        // fractional k (dynamic skip): thin loads proportionally
        let frac = k / k_int as f64;
        let tiles: Vec<u64> = loads
            .iter()
            .map(|&l| {
                let eff = (l as f64 * scale * frac).round() as u64;
                eff.div_ceil(hw.moe_tile_rows as u64)
            })
            .collect();
        let tile_flops = hw.moe_tile_rows as f64 * 3.0 * 2.0 * h as f64 * geom.ffn as f64
            / g as f64;
        let tile_time = tile_flops / hw.eff_flops();
        let makespan = lpt_makespan(&tiles, hw.sm_lanes, tile_time);
        let active = tiles.iter().filter(|&&t| t > 0).count();
        let weight_bytes = active as f64 * geom.expert_weight_bytes(hw.dtype_bytes) / g as f64;
        let moe_compute = makespan.max(weight_bytes / hw.eff_bw()) + hw.kernel_overhead;
        let dispatch = stream_time(hw, dispatch_combine_bytes(hw, tokens, h, k));

        // load-imbalance bookkeeping
        let mean_load = loads.iter().sum::<u64>() as f64 / loads.len() as f64;
        let max_load = *loads.iter().max().unwrap() as f64;
        *imbalance_out += max_load / mean_load.max(1e-12);

        // Two TP all-reduces per layer (post-attention, post-MoE).
        let ar_bytes = (tokens * h * hw.dtype_bytes) as f64;
        let comm = 2.0 * allreduce_time(hw, ar_bytes, g);

        // Non-resident active experts stream over the host link.
        let fetch =
            self.host_fetch_time(geom, self.residency_miss(geom, routing, j, active as f64));

        (attn + router, moe_compute + dispatch + fetch, comm, fetch)
    }

    /// One layer's decode-step time for `batch` sequences at context `ctx`.
    fn layer_decode(
        &self,
        geom: &LayerGeom,
        routing: &LayerRouting,
        j: usize,
        batch: usize,
        ctx: usize,
        k: f64,
    ) -> (f64, f64, f64, f64) {
        let hw = &self.hw;
        let g = self.spec.paper.n_gpus;
        let h = geom.hidden;

        // Attention: weight read + KV read dominate (memory-bound).
        let attn_bytes = geom.attn_weight_bytes(hw.dtype_bytes) / g as f64
            + (batch * ctx * 2 * h / g * hw.dtype_bytes) as f64;
        let attn = stream_time(hw, attn_bytes) + 3.0 * hw.kernel_overhead;

        // Experts: expected distinct active experts drive weight traffic.
        let k_int = (k.ceil() as usize).max(1);
        let stats = routing.stats(j, batch, k_int, self.trials, self.seed + 31 * j as u64);
        let active = stats
            .expected_active_experts
            .min(geom.n_experts as f64)
            .max(1.0);
        let weight_bytes = active * geom.expert_weight_bytes(hw.dtype_bytes) / g as f64;
        let flops = batch as f64 * k * 3.0 * 2.0 * h as f64 * geom.ffn as f64 / g as f64;
        // tile quantization: each active expert is at least one tile
        let tile_flops =
            hw.moe_tile_rows as f64 * 3.0 * 2.0 * h as f64 * geom.ffn as f64 / g as f64;
        let quantized_flops = (active * tile_flops).max(flops);
        let lanes_spans = (active / hw.sm_lanes as f64).ceil().max(1.0);
        let moe = (quantized_flops / hw.eff_flops() * lanes_spans)
            .max(weight_bytes / hw.eff_bw())
            + hw.kernel_overhead
            + stream_time(hw, dispatch_combine_bytes(hw, batch, h, k));

        let ar_bytes = (batch * h * hw.dtype_bytes) as f64;
        let comm = 2.0 * allreduce_time(hw, ar_bytes, g);
        let fetch = self.host_fetch_time(geom, self.residency_miss(geom, routing, j, active));
        (attn, moe + fetch, comm, fetch)
    }

    /// End-to-end throughput under the paper's workload: `batch` requests
    /// of `in_len` prompt tokens and `out_len` generated tokens.
    pub fn throughput(
        &self,
        t: &Transform,
        batch: usize,
        in_len: usize,
        out_len: usize,
    ) -> PerfBreakdown {
        let routing = self.routing_for(t);
        let ks = self.k_eff(t, &routing);
        self.throughput_impl(t, routing, ks, batch, in_len, out_len)
    }

    /// Throughput with a transform's geometry/routing but an explicit
    /// per-layer k (Fig. 2 sweeps top-k on top of each pruning level).
    pub fn throughput_with_k(
        &self,
        t: &Transform,
        alloc: &crate::moe::allocation::Allocation,
        batch: usize,
        in_len: usize,
        out_len: usize,
    ) -> PerfBreakdown {
        let routing = self.routing_for(t);
        let ks: Vec<f64> = alloc.k.iter().map(|&k| k as f64).collect();
        self.throughput_impl(t, routing, ks, batch, in_len, out_len)
    }

    fn throughput_impl(
        &self,
        t: &Transform,
        routing: LayerRouting,
        ks: Vec<f64>,
        batch: usize,
        in_len: usize,
        out_len: usize,
    ) -> PerfBreakdown {
        let geom = self.geom(t);
        let l = &geom.layer;

        let mut out = PerfBreakdown::default();
        let prefill_tokens = batch * in_len;
        let mut imb = 0.0;
        for j in 0..geom.n_layers {
            let (a, m, c, f) =
                self.layer_prefill(l, &routing, j, prefill_tokens, in_len, ks[j], &mut imb);
            out.attn_s += a;
            out.moe_s += m;
            out.comm_s += c;
            out.expert_fetch_s += f;
            out.prefill_s += a + m + c;
        }
        out.mean_imbalance = imb / geom.n_layers as f64;

        // Decode: context grows; evaluate at the midpoint context.
        let ctx = in_len + out_len / 2;
        let mut step = 0.0;
        for j in 0..geom.n_layers {
            let (a, m, c, f) = self.layer_decode(l, &routing, j, batch, ctx, ks[j]);
            out.attn_s += a * out_len as f64;
            out.moe_s += m * out_len as f64;
            out.comm_s += c * out_len as f64;
            out.expert_fetch_s += f * out_len as f64;
            step += a + m + c;
        }
        // Unembedding each step.
        let unembed = gemm_time(&self.hw, batch, geom.vocab / self.spec.paper.n_gpus, l.hidden);
        out.decode_s = (step + unembed) * out_len as f64;

        out.total_s = out.prefill_s + out.decode_s;
        out.throughput_tok_s = (batch * (in_len + out_len)) as f64 / out.total_s;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::spec;
    use crate::moe::allocation::Allocation;

    fn model(name: &str) -> PerfModel {
        PerfModel::new(spec(name).unwrap(), 0)
    }

    #[test]
    fn lexi_lower_k_raises_throughput() {
        let pm = model("qwen1.5-moe-a2.7b");
        let base = pm.throughput(&Transform::Baseline, 16, 1024, 512);
        let lexi = pm.throughput(
            &Transform::Lexi {
                allocation: Allocation::uniform(24, 2),
            },
            16,
            1024,
            512,
        );
        assert!(
            lexi.throughput_tok_s > base.throughput_tok_s,
            "lexi {} <= base {}",
            lexi.throughput_tok_s,
            base.throughput_tok_s
        );
    }

    #[test]
    fn inter_pruning_is_roughly_throughput_neutral() {
        // The paper's central empirical claim (Fig. 2): expert pruning
        // does not buy anywhere near the proportional speedup.
        let pm = model("olmoe-1b-7b");
        let base = pm.throughput(&Transform::Baseline, 16, 1024, 512);
        let pruned = pm.throughput(&Transform::InterPrune { frac: 0.5 }, 16, 1024, 512);
        let ratio = pruned.throughput_tok_s / base.throughput_tok_s;
        assert!(
            (0.7..1.35).contains(&ratio),
            "50% inter-pruning changed throughput by {ratio}x (removed half the \
             weights but throughput moved far less — the Fig. 2 observation)"
        );
        // while LExI at half the budget matches or beats it AND clearly
        // beats the baseline (the paper's Fig. 4 geometry)
        let lexi = pm.throughput(
            &Transform::Lexi {
                allocation: Allocation::uniform(16, 4),
            },
            16,
            1024,
            512,
        );
        assert!(lexi.throughput_tok_s > base.throughput_tok_s * 1.05);
        assert!(lexi.throughput_tok_s > pruned.throughput_tok_s * 0.9);
    }

    #[test]
    fn intra_pruning_gives_modest_gains() {
        let pm = model("mixtral-8x7b");
        let base = pm.throughput(&Transform::Baseline, 16, 1024, 512);
        let intra = pm.throughput(&Transform::IntraPrune { frac: 0.5 }, 16, 1024, 512);
        assert!(intra.throughput_tok_s >= base.throughput_tok_s * 0.95);
        assert!(intra.throughput_tok_s <= base.throughput_tok_s * 2.2);
    }

    #[test]
    fn dynamic_skip_between_k1_and_k2() {
        let pm = model("mixtral-8x7b");
        let base = pm.throughput(&Transform::Baseline, 16, 1024, 512);
        let k1 = pm.throughput(
            &Transform::Lexi {
                allocation: Allocation::uniform(32, 1),
            },
            16,
            1024,
            512,
        );
        let skip = pm.throughput(&Transform::DynamicSkip { threshold: 0.5 }, 16, 1024, 512);
        assert!(skip.throughput_tok_s >= base.throughput_tok_s * 0.98);
        assert!(skip.throughput_tok_s <= k1.throughput_tok_s * 1.02);
    }

    #[test]
    fn lattice_axis_transforms_price_honestly() {
        // The 2-D quality lattice's second axis must buy real modeled
        // latency: at a fixed Stage-2 allocation, shrinking the FFN dim
        // (intra) or skipping weak 2nd experts must not be slower, and
        // intra must strictly beat the same allocation dense — decode is
        // memory-bound, so cutting weight bytes cuts step time.
        let pm = model("mixtral-8x7b"); // k_base = 2: skip is applicable
        let alloc = Allocation::uniform(32, 2);
        let lexi = pm.throughput(
            &Transform::Lexi { allocation: alloc.clone() },
            16,
            1024,
            512,
        );
        let intra = pm.throughput(
            &Transform::LexiPlusIntra { allocation: alloc.clone(), frac: 0.5 },
            16,
            1024,
            512,
        );
        let skip = pm.throughput(
            &Transform::LexiPlusSkip { allocation: alloc.clone(), threshold: 0.5 },
            16,
            1024,
            512,
        );
        assert!(
            intra.throughput_tok_s > lexi.throughput_tok_s,
            "intra {} <= dense {}",
            intra.throughput_tok_s,
            lexi.throughput_tok_s
        );
        assert!(skip.throughput_tok_s >= lexi.throughput_tok_s * 0.98);
        // skipping cannot beat running every layer at k=1 outright
        let k1 = pm.throughput(
            &Transform::Lexi { allocation: Allocation::uniform(32, 1) },
            16,
            1024,
            512,
        );
        assert!(skip.throughput_tok_s <= k1.throughput_tok_s * 1.02);
    }

    #[test]
    fn hbm_budget_charges_expert_traffic() {
        let spec = spec("qwen1.5-moe-a2.7b").unwrap();
        let geom = crate::moe::arch::ModelGeom::paper_scale(&spec);
        let total = geom.expert_param_count() * 2.0 / spec.paper.n_gpus as f64;
        let free = model("qwen1.5-moe-a2.7b");
        let tight = PerfModel::new(spec.clone(), 0).with_hbm_budget_bytes(total * 0.3);
        let loose = PerfModel::new(spec.clone(), 0).with_hbm_budget_bytes(total * 0.7);

        let b_free = free.throughput(&Transform::Baseline, 16, 1024, 512);
        let b_tight = tight.throughput(&Transform::Baseline, 16, 1024, 512);
        let b_loose = loose.throughput(&Transform::Baseline, 16, 1024, 512);
        // no budget -> no fetch term, identical numbers
        assert_eq!(b_free.expert_fetch_s, 0.0);
        // a budget costs throughput, monotonically in tightness
        assert!(b_tight.expert_fetch_s > b_loose.expert_fetch_s);
        assert!(b_tight.throughput_tok_s < b_loose.throughput_tok_s);
        assert!(b_loose.throughput_tok_s <= b_free.throughput_tok_s);

        // LExI's smaller active sets shed proportionally more of the
        // fetch traffic than the uniform baseline pays (the memory-side
        // win invisible before this term existed)
        let lexi = Transform::Lexi {
            allocation: Allocation::uniform(spec.n_layers, 2),
        };
        let l_tight = tight.throughput(&lexi, 16, 1024, 512);
        assert!(l_tight.expert_fetch_s < b_tight.expert_fetch_s);
        assert!(l_tight.throughput_tok_s > b_tight.throughput_tok_s);
        // a budget covering everything is a no-op
        let roomy = PerfModel::new(spec, 0).with_hbm_budget_bytes(total * 2.0);
        let b_roomy = roomy.throughput(&Transform::Baseline, 16, 1024, 512);
        assert_eq!(b_roomy.expert_fetch_s, 0.0);
        assert!((b_roomy.throughput_tok_s - b_free.throughput_tok_s).abs() < 1e-9);
    }

    #[test]
    fn breakdown_sums() {
        let pm = model("deepseek-v2-lite");
        let b = pm.throughput(&Transform::Baseline, 16, 512, 256);
        assert!(b.prefill_s > 0.0 && b.decode_s > 0.0);
        assert!((b.total_s - b.prefill_s - b.decode_s).abs() < 1e-12);
        assert!(b.mean_imbalance >= 1.0);
        let sum = b.attn_s + b.moe_s + b.comm_s;
        // unembed is outside the three buckets
        assert!(sum <= b.total_s + 1e-9);
    }
}
