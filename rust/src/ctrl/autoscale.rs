//! Telemetry-driven replica autoscaling with priced warmup.
//!
//! The autoscaler manages a fixed-capacity pool of replica slots
//! (`max` backends exist for the whole run; indices are stable) and
//! moves each through `Retired → Warming → Active → Draining →
//! Retired`. Decisions are pure functions of the per-instant
//! [`ClusterSnapshot`]: scale UP when the cluster's projected
//! interactive slack or outstanding depth shows *sustained* pressure,
//! scale DOWN (drain, then retire once empty) on sustained idle.
//! Draining replicas finish the work they hold but stop accepting new
//! routing, so no request is ever lost to a retirement.
//!
//! Spin-up is not free: a freshly activated replica must fetch its
//! pinned expert hot set and the Stage-1 sensitivity table over the
//! host link before serving, priced by [`warmup_cost_s`] through the
//! residency model's [`LinkModel`] — the same constants demand misses
//! pay under an HBM budget.

use crate::experts::ResidencyConfig;
use crate::server::telemetry::ClusterSnapshot;

/// Lifecycle state of one replica slot under the autoscaler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReplicaState {
    /// Serving: accepts routed work.
    Active,
    /// Spinning up (expert prewarm + table load in flight); activates
    /// at the first control instant at or after `ready_at_s`.
    Warming { ready_at_s: f64 },
    /// Finishing held work; accepts nothing new.
    Draining,
    /// Off: costs nothing, holds nothing.
    Retired,
}

/// Declarative autoscaler thresholds. Time windows are derived from the
/// service model's full-batch decode step so the controller's reaction
/// speed scales with the hardware, not with a wall-clock constant.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    /// Replica-count floor (never drain below).
    pub min: usize,
    /// Replica-count ceiling (= the backend pool size).
    pub max: usize,
    /// Priced spin-up delay between the scale-up decision and the
    /// replica accepting work (see [`warmup_cost_s`]).
    pub warmup_s: f64,
    /// Scale up while the worst projected interactive slack fraction
    /// sits below this (the ladder's degrade threshold by default).
    pub up_slack_frac: f64,
    /// ... or while outstanding work per live replica exceeds this many
    /// multiples of its slot count.
    pub up_outstanding_per_slot: f64,
    /// Drain one replica when the remaining live set could hold all
    /// outstanding work at this occupancy fraction.
    pub down_outstanding_per_slot: f64,
    /// Pressure must persist this long before a scale-up fires.
    pub sustain_up_s: f64,
    /// Idle must persist this long before a drain fires (longer than
    /// the up window: capacity mistakes are cheaper than SLO misses).
    pub sustain_down_s: f64,
    /// Minimum time between consecutive scaling actions.
    pub cooldown_s: f64,
    /// Decode slots per replica (the occupancy unit of the thresholds).
    pub slots_per_replica: usize,
}

impl AutoscalePolicy {
    /// Policy for a cluster whose full-batch decode step is `step_s`:
    /// sustain/cooldown windows in step units, slack threshold shared
    /// with the ladder's degrade fraction.
    pub fn for_cluster(
        min: usize,
        max: usize,
        slots_per_replica: usize,
        step_s: f64,
        warmup_s: f64,
        up_slack_frac: f64,
    ) -> Self {
        AutoscalePolicy {
            min,
            max,
            warmup_s,
            up_slack_frac,
            up_outstanding_per_slot: 1.5,
            down_outstanding_per_slot: 0.5,
            sustain_up_s: (10.0 * step_s).max(0.02),
            sustain_down_s: (80.0 * step_s).max(0.2),
            cooldown_s: (20.0 * step_s).max(0.05).max(warmup_s),
            slots_per_replica,
        }
    }
}

/// What one control instant decided (the cluster loop turns these into
/// trace events and report rows).
#[derive(Clone, Debug, Default)]
pub struct ScaleActions {
    /// Replicas that finished warming and now accept work.
    pub activated: Vec<usize>,
    /// Replicas that began draining toward retirement.
    pub drained: Vec<usize>,
}

/// The autoscaler: per-slot lifecycle states plus the sustained
/// pressure/idle detectors and replica-second accounting.
#[derive(Clone, Debug)]
pub struct Autoscaler {
    pub policy: AutoscalePolicy,
    /// Lifecycle state per replica slot (indexed like the backends).
    pub states: Vec<ReplicaState>,
    /// Provisioned replica-seconds (Active + Warming + Draining time) —
    /// the cost side of the elasticity trade.
    pub replica_seconds: f64,
    pressure_since: Option<f64>,
    idle_since: Option<f64>,
    last_action_s: f64,
    last_account_s: f64,
}

impl Autoscaler {
    /// `total` replica slots with the first `initial_live` (clamped
    /// into `[min, max]`) starting Active, the rest Retired.
    pub fn new(policy: AutoscalePolicy, total: usize, initial_live: usize) -> Self {
        let live = initial_live.clamp(policy.min, policy.max).min(total);
        Autoscaler {
            states: (0..total)
                .map(|i| {
                    if i < live {
                        ReplicaState::Active
                    } else {
                        ReplicaState::Retired
                    }
                })
                .collect(),
            policy,
            replica_seconds: 0.0,
            pressure_since: None,
            idle_since: None,
            last_action_s: f64::NEG_INFINITY,
            last_account_s: 0.0,
        }
    }

    /// Whether the replica accepts new routed work right now.
    pub fn accepting(&self, replica: usize) -> bool {
        matches!(self.states[replica], ReplicaState::Active)
    }

    /// Currently serving replicas.
    pub fn live(&self) -> usize {
        self.states
            .iter()
            .filter(|s| matches!(s, ReplicaState::Active))
            .count()
    }

    fn warming(&self) -> usize {
        self.states
            .iter()
            .filter(|s| matches!(s, ReplicaState::Warming { .. }))
            .count()
    }

    /// Replicas currently costing money (everything but Retired).
    fn provisioned(&self) -> usize {
        self.states
            .iter()
            .filter(|s| !matches!(s, ReplicaState::Retired))
            .count()
    }

    /// Mask the snapshot so routing/stealing only see Active replicas
    /// as accepting (composes with backend health via `&=`).
    pub fn mask(&self, snap: &mut ClusterSnapshot) {
        for t in &mut snap.replicas {
            t.accepting &= self.accepting(t.replica);
        }
    }

    /// One control instant: account provisioned time, promote warmed
    /// replicas, retire empty drained ones, then run the sustained
    /// pressure/idle detectors. The snapshot must cover every slot.
    pub fn step(&mut self, snap: &ClusterSnapshot) -> ScaleActions {
        let now = snap.now_s;
        self.account(now);
        let mut out = ScaleActions::default();

        for (i, st) in self.states.iter_mut().enumerate() {
            match *st {
                ReplicaState::Warming { ready_at_s } if ready_at_s <= now => {
                    *st = ReplicaState::Active;
                    out.activated.push(i);
                }
                ReplicaState::Draining if snap.replicas[i].outstanding() == 0 => {
                    *st = ReplicaState::Retired;
                }
                _ => {}
            }
        }

        let live = self.live();
        let slots = self.policy.slots_per_replica as f64;
        let outstanding: usize = snap
            .replicas
            .iter()
            .filter(|t| matches!(self.states[t.replica], ReplicaState::Active))
            .map(|t| t.outstanding())
            .sum();
        let slack = snap.min_projected_interactive_slack_frac();
        let pressured = slack < self.policy.up_slack_frac
            || outstanding as f64 > self.policy.up_outstanding_per_slot * live as f64 * slots;
        let idle = live > self.policy.min
            && (outstanding as f64)
                < self.policy.down_outstanding_per_slot * (live - 1) as f64 * slots;

        if pressured {
            self.idle_since = None;
            let since = *self.pressure_since.get_or_insert(now);
            if now - since >= self.policy.sustain_up_s
                && now - self.last_action_s >= self.policy.cooldown_s
                && live + self.warming() < self.policy.max
            {
                if let Some(i) = self
                    .states
                    .iter()
                    .position(|s| matches!(s, ReplicaState::Retired))
                {
                    self.states[i] = ReplicaState::Warming {
                        ready_at_s: now + self.policy.warmup_s,
                    };
                    self.last_action_s = now;
                    self.pressure_since = None; // re-arm the detector
                }
            }
        } else if idle {
            self.pressure_since = None;
            let since = *self.idle_since.get_or_insert(now);
            // never drain while a warmup is in flight: the two actions
            // would fight each other across the cooldown
            if now - since >= self.policy.sustain_down_s
                && now - self.last_action_s >= self.policy.cooldown_s
                && self.warming() == 0
            {
                // drain the highest-index Active slot so the stable
                // front of the pool stays hot
                if let Some(i) = self
                    .states
                    .iter()
                    .rposition(|s| matches!(s, ReplicaState::Active))
                {
                    self.states[i] = ReplicaState::Draining;
                    out.drained.push(i);
                    self.last_action_s = now;
                    self.idle_since = None;
                }
            }
        } else {
            self.pressure_since = None;
            self.idle_since = None;
        }
        out
    }

    /// Fold provisioned replica time up to `now` into the accumulator.
    pub fn account(&mut self, now: f64) {
        self.replica_seconds += self.provisioned() as f64 * (now - self.last_account_s).max(0.0);
        self.last_account_s = now;
    }
}

/// Price one replica's spin-up: fetch the pinned expert hot set (the
/// live `k_vec`'s per-layer experts) plus the Stage-1 sensitivity table
/// over the residency model's host link — the same [`LinkModel`]
/// constants demand misses pay. 8 bytes per table cell (an f64 loss).
///
/// [`LinkModel`]: crate::experts::store::LinkModel
pub fn warmup_cost_s(rc: &ResidencyConfig, k_vec: &[i32]) -> f64 {
    let hot_bytes: u64 = k_vec.iter().map(|&k| k.max(0) as u64 * rc.expert_bytes).sum();
    let table_bytes = (rc.n_layers * rc.n_experts * 8) as u64;
    rc.link.fetch_s(hot_bytes) + rc.link.fetch_s(table_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::server::EvictKind;
    use crate::server::telemetry::ReplicaTelemetry;

    fn policy(min: usize, max: usize) -> AutoscalePolicy {
        AutoscalePolicy {
            min,
            max,
            warmup_s: 0.5,
            up_slack_frac: 0.25,
            up_outstanding_per_slot: 1.5,
            down_outstanding_per_slot: 0.5,
            sustain_up_s: 1.0,
            sustain_down_s: 2.0,
            cooldown_s: 0.5,
            slots_per_replica: 4,
        }
    }

    fn snap(now_s: f64, outstanding: &[usize]) -> ClusterSnapshot {
        ClusterSnapshot {
            now_s,
            replicas: outstanding
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    let mut t = ReplicaTelemetry::idle(i);
                    t.queue_len = n;
                    t
                })
                .collect(),
        }
    }

    #[test]
    fn sustained_pressure_warms_then_activates() {
        let mut a = Autoscaler::new(policy(1, 3), 3, 1);
        assert_eq!(a.live(), 1);
        // heavy backlog on the one live replica: 20 > 1.5 * 1 * 4
        let hot = |t| snap(t, &[20, 0, 0]);
        assert!(a.step(&hot(0.0)).activated.is_empty()); // detector arms
        assert!(a.step(&hot(0.5)).activated.is_empty()); // not sustained yet
        let acts = a.step(&hot(1.5)); // sustained past 1.0s -> warm slot 1
        assert!(acts.activated.is_empty(), "warmup is not instantaneous");
        assert!(matches!(a.states[1], ReplicaState::Warming { .. }));
        assert!(!a.accepting(1), "warming replica must not accept work");
        // past ready_at (1.5 + 0.5): slot 1 activates
        let acts = a.step(&hot(2.1));
        assert_eq!(acts.activated, vec![1]);
        assert!(a.accepting(1));
        assert_eq!(a.live(), 2);
    }

    #[test]
    fn sustained_idle_drains_then_retires_highest_index() {
        let mut a = Autoscaler::new(policy(1, 3), 3, 3);
        assert_eq!(a.live(), 3);
        // nearly empty cluster: 1 < 0.5 * 2 * 4
        let calm = |t| snap(t, &[1, 0, 0]);
        assert!(a.step(&calm(0.0)).drained.is_empty());
        let acts = a.step(&calm(2.5)); // sustained past 2.0s
        assert_eq!(acts.drained, vec![2], "highest-index Active drains first");
        assert!(matches!(a.states[2], ReplicaState::Draining));
        assert!(!a.accepting(2));
        // still holding work: stays Draining
        a.step(&snap(3.0, &[1, 0, 4]));
        assert!(matches!(a.states[2], ReplicaState::Draining));
        // empty now: retires without an event
        a.step(&snap(3.5, &[1, 0, 0]));
        assert!(matches!(a.states[2], ReplicaState::Retired));
        assert_eq!(a.live(), 2);
    }

    #[test]
    fn never_drains_below_min_or_grows_past_max() {
        let mut a = Autoscaler::new(policy(2, 3), 3, 2);
        let calm = |t| snap(t, &[0, 0, 0]);
        for i in 0..20 {
            a.step(&calm(i as f64));
        }
        assert_eq!(a.live(), 2, "drained below min");

        let mut a = Autoscaler::new(policy(1, 2), 2, 2);
        let hot = |t| snap(t, &[30, 30]);
        for i in 0..20 {
            a.step(&hot(i as f64));
        }
        assert_eq!(a.live(), 2, "grew past max");
    }

    #[test]
    fn collapsing_slack_is_pressure_even_at_low_depth() {
        let mut a = Autoscaler::new(policy(1, 2), 2, 1);
        let mk = |t: f64| {
            let mut s = snap(t, &[1, 0]);
            s.replicas[0].projected_interactive_slack_frac = Some(0.1);
            s
        };
        a.step(&mk(0.0));
        a.step(&mk(1.5));
        assert!(
            matches!(a.states[1], ReplicaState::Warming { .. }),
            "slack collapse must trigger scale-up"
        );
    }

    #[test]
    fn replica_seconds_track_provisioned_time() {
        let mut a = Autoscaler::new(policy(1, 2), 2, 1);
        a.step(&snap(1.0, &[0, 0]));
        assert!((a.replica_seconds - 1.0).abs() < 1e-9);
        a.account(3.0);
        assert!((a.replica_seconds - 3.0).abs() < 1e-9);
    }

    #[test]
    fn mask_composes_with_backend_health() {
        let a = Autoscaler::new(policy(1, 3), 3, 1);
        let mut s = snap(0.0, &[0, 0, 0]);
        a.mask(&mut s);
        assert!(s.replicas[0].accepting);
        assert!(!s.replicas[1].accepting && !s.replicas[2].accepting);
    }

    #[test]
    fn warmup_prices_hot_set_and_table_over_the_link() {
        let rc = ResidencyConfig::for_dims(4, 8, 1 << 20, 1.0, EvictKind::KvecAware, 0);
        let cheap = warmup_cost_s(&rc, &[1, 1, 1, 1]);
        let dear = warmup_cost_s(&rc, &[4, 4, 4, 4]);
        assert!(cheap > 0.0);
        assert!(dear > cheap, "more pinned experts must cost more");
        // analytic check: hot bytes + table bytes over the link, plus
        // two issue latencies
        let expect = rc.link.fetch_s(4 * (1 << 20)) + rc.link.fetch_s(4 * 8 * 8);
        assert!((cheap - expect).abs() < 1e-12);
    }
}
