//! Class-aware admission shedding with SLO-relative thresholds.
//!
//! The pass-through [`AdmissionControl`](crate::server::scheduler::AdmissionControl)
//! rejects whatever arrives once the outstanding cap is hit — including
//! interactive traffic the cluster exists to protect. The shedder sits
//! in front of the cap and sheds *batch-priority* work earlier, on the
//! same two pressure signals the quality ladder reads: outstanding
//! depth relative to the cap, and the cluster's worst projected
//! interactive EDF slack. Interactive (priority-0) requests are never
//! policy-shed; only the hard cap can turn them away.
//!
//! Thresholds are graduated by priority: the lower a class's priority
//! (higher numeric value), the earlier it sheds, so a flash crowd burns
//! background batch first, then best-effort, and touches interactive
//! last.

use crate::config::server::ServerConfig;
use crate::server::telemetry::ClusterSnapshot;

/// Declarative shedding thresholds (all SLO/cap-relative).
#[derive(Clone, Debug)]
pub struct ShedPolicy {
    /// The hard admission cap the queue thresholds are fractions of.
    pub cap: usize,
    /// Outstanding-work fraction of the cap at which priority-1 traffic
    /// sheds; priority `p` sheds at `cap * queue_frac^p`, so deeper
    /// batch tiers shed earlier.
    pub queue_frac: f64,
    /// Shed all batch traffic while the cluster's worst *projected*
    /// interactive slack fraction sits below this (the ladder's degrade
    /// threshold by default): queued interactive deadlines are already
    /// collapsing, so batch admissions would only steal their service.
    pub slack_frac: f64,
}

impl ShedPolicy {
    /// Thresholds mirroring the ladder controller's pressure config.
    pub fn from_config(cfg: &ServerConfig) -> Self {
        ShedPolicy {
            cap: cfg.queue_cap,
            queue_frac: 0.85,
            slack_frac: cfg.slack_degrade_frac,
        }
    }

    /// Outstanding-work threshold at which priority `p` traffic sheds.
    pub fn queue_threshold(&self, priority: u8) -> usize {
        (self.cap as f64 * self.queue_frac.powi(priority as i32)).floor() as usize
    }
}

/// Stateful shedder: the policy plus per-class shed counters.
#[derive(Clone, Debug)]
pub struct Shedder {
    pub policy: ShedPolicy,
    /// Requests shed per SLO class (index = class id).
    pub shed_by_class: Vec<u64>,
    /// Latest health-engine burn reading (`--pressure burn` only): a
    /// slack-like fraction, `None` when burn pressure is off or the
    /// engine has no evidence yet.
    burn_frac: Option<f64>,
}

impl Shedder {
    pub fn new(policy: ShedPolicy, n_classes: usize) -> Self {
        Shedder {
            policy,
            shed_by_class: vec![0; n_classes],
            burn_frac: None,
        }
    }

    /// Feed the health engine's burn reading ahead of the arrival
    /// decisions of a control instant (see
    /// [`HealthEngine::burn_frac`](crate::obs::health::HealthEngine::burn_frac)).
    pub fn set_burn_frac(&mut self, frac: Option<f64>) {
        self.burn_frac = frac;
    }

    /// Decide one arrival: `Some(reason)` means shed (and the per-class
    /// counter has been charged), `None` means pass it on to the hard
    /// cap. Pure in the snapshot — only the counters mutate.
    pub fn decide(
        &mut self,
        snap: &ClusterSnapshot,
        outstanding: usize,
        class: usize,
        priority: u8,
    ) -> Option<&'static str> {
        if priority == 0 {
            return None;
        }
        let reason = if outstanding >= self.policy.queue_threshold(priority) {
            Some("queue")
        } else if snap.min_projected_interactive_slack_frac() < self.policy.slack_frac {
            Some("slack")
        } else if self.burn_frac.is_some_and(|f| f < self.policy.slack_frac) {
            // the error budget is burning critically fast: batch
            // admissions would only deepen it
            Some("burn")
        } else {
            None
        };
        if reason.is_some() {
            if class >= self.shed_by_class.len() {
                self.shed_by_class.resize(class + 1, 0);
            }
            self.shed_by_class[class] += 1;
        }
        reason
    }

    /// Total requests shed across classes.
    pub fn total(&self) -> u64 {
        self.shed_by_class.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::telemetry::ReplicaTelemetry;

    fn policy() -> ShedPolicy {
        ShedPolicy {
            cap: 100,
            queue_frac: 0.8,
            slack_frac: 0.25,
        }
    }

    fn calm_snap() -> ClusterSnapshot {
        ClusterSnapshot {
            now_s: 0.0,
            replicas: vec![ReplicaTelemetry::idle(0)],
        }
    }

    #[test]
    fn thresholds_graduate_by_priority() {
        let p = policy();
        assert_eq!(p.queue_threshold(1), 80);
        assert_eq!(p.queue_threshold(2), 64);
        assert!(p.queue_threshold(2) < p.queue_threshold(1));
        assert!(p.queue_threshold(1) < p.cap);
    }

    #[test]
    fn interactive_is_never_policy_shed() {
        let mut s = Shedder::new(policy(), 3);
        // even at (and past) the cap, priority 0 passes through to the
        // hard cap — the shedder never touches it
        assert_eq!(s.decide(&calm_snap(), 1000, 0, 0), None);
        assert_eq!(s.total(), 0);
    }

    #[test]
    fn batch_sheds_on_queue_pressure_deepest_first() {
        let mut s = Shedder::new(policy(), 3);
        let snap = calm_snap();
        // at outstanding=70: priority 2 (threshold 64) sheds, priority 1
        // (threshold 80) still passes
        assert_eq!(s.decide(&snap, 70, 2, 2), Some("queue"));
        assert_eq!(s.decide(&snap, 70, 1, 1), None);
        // at 85 priority 1 sheds too
        assert_eq!(s.decide(&snap, 85, 1, 1), Some("queue"));
        assert_eq!(s.shed_by_class, vec![0, 1, 1]);
        assert_eq!(s.total(), 2);
    }

    #[test]
    fn collapsing_interactive_slack_sheds_all_batch() {
        let mut s = Shedder::new(policy(), 3);
        let mut t = ReplicaTelemetry::idle(0);
        t.projected_interactive_slack_frac = Some(0.1); // below 0.25
        let snap = ClusterSnapshot {
            now_s: 1.0,
            replicas: vec![t],
        };
        // outstanding is low, but interactive deadlines are collapsing
        assert_eq!(s.decide(&snap, 1, 1, 1), Some("slack"));
        assert_eq!(s.decide(&snap, 1, 2, 2), Some("slack"));
        // interactive still passes
        assert_eq!(s.decide(&snap, 1, 0, 0), None);
    }

    #[test]
    fn critical_burn_sheds_batch_but_not_interactive() {
        let mut s = Shedder::new(policy(), 3);
        let snap = calm_snap();
        s.set_burn_frac(Some(0.1)); // below slack_frac 0.25
        assert_eq!(s.decide(&snap, 1, 1, 1), Some("burn"));
        assert_eq!(s.decide(&snap, 1, 0, 0), None);
        // healthy burn reading sheds nothing
        s.set_burn_frac(Some(0.9));
        assert_eq!(s.decide(&snap, 1, 1, 1), None);
    }

    #[test]
    fn calm_cluster_sheds_nothing() {
        let mut s = Shedder::new(policy(), 3);
        let snap = calm_snap(); // no queued interactive -> slack = +inf
        for p in 1..=2u8 {
            assert_eq!(s.decide(&snap, 10, p as usize, p), None);
        }
        assert_eq!(s.total(), 0);
    }
}
