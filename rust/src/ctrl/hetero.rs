//! Heterogeneous replica hardware tiers (mixed H100/A100 clusters).
//!
//! `--replica-tiers h100:4,a100:4` assigns each replica slot a
//! [`Hardware`] constant set in spec order, and every point of that
//! replica's quality lattice (every (k, s) coordinate, both axes) gets
//! a service model recomputed from the tier's perf model — so an A100
//! replica really is ~3x slower per step, and its `step_ewma_s`
//! telemetry says so.
//!
//! Routing and stealing learn about speed through
//! [`reweight_by_speed`]: the snapshot's token-backlog `load_cost` is
//! rescaled into estimated *drain time* using each replica's step-time
//! EWMA, so every load-based decision (JSQ, p2c, class-aware
//! tie-breaks, steal-victim selection) weighs how fast a replica burns
//! work, not just how much it holds.

use anyhow::{ensure, Result};

use crate::config::server::TierKind;
use crate::perfmodel::Hardware;
use crate::server::telemetry::ClusterSnapshot;

/// The hardware constant set of a tier.
pub fn hardware_for(tier: TierKind) -> Hardware {
    match tier {
        TierKind::H100 => Hardware::h100(),
        TierKind::A100 => Hardware::a100(),
    }
}

/// Expand a `tier:count` spec into one tier per replica slot, in spec
/// order (the first entry takes the lowest replica indices).
pub fn expand_tiers(spec: &[(TierKind, usize)]) -> Vec<TierKind> {
    spec.iter()
        .flat_map(|&(tier, n)| std::iter::repeat(tier).take(n))
        .collect()
}

/// A tier spec must cover the cluster exactly.
pub fn validate_tiers(spec: &[(TierKind, usize)], replicas: usize) -> Result<()> {
    let total: usize = spec.iter().map(|&(_, n)| n).sum();
    ensure!(
        total == replicas,
        "--replica-tiers counts sum to {total} but the cluster has {replicas} replicas"
    );
    Ok(())
}

/// Rescale every replica's `load_cost` from token backlog into
/// estimated drain time (integer nanoseconds): `(load + 1) *
/// step_ewma_s`. The `+1` keeps empty replicas ordered by speed, so
/// load ties break toward the faster tier. Replicas with no step
/// history yet are priced at the slowest observed EWMA (pessimistic —
/// a cold replica never looks artificially fast). No-op until at least
/// one replica has step history.
pub fn reweight_by_speed(snap: &mut ClusterSnapshot) {
    let max_e = snap
        .replicas
        .iter()
        .map(|t| t.step_ewma_s)
        .fold(0.0f64, f64::max);
    if max_e <= 0.0 {
        return;
    }
    for t in &mut snap.replicas {
        let e = if t.step_ewma_s > 0.0 { t.step_ewma_s } else { max_e };
        t.load_cost = ((t.load_cost + 1) as f64 * e * 1e9).round() as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::telemetry::ReplicaTelemetry;

    #[test]
    fn a100_is_a_slower_tier_than_h100() {
        let h = hardware_for(TierKind::H100);
        let a = hardware_for(TierKind::A100);
        assert!(a.peak_flops < h.peak_flops);
        assert!(a.hbm_bw < h.hbm_bw);
        assert!(a.host_link_bw < h.host_link_bw);
        assert!(a.eff_flops() < h.eff_flops());
    }

    #[test]
    fn expand_assigns_low_indices_to_the_first_entry() {
        let tiers = expand_tiers(&[(TierKind::H100, 2), (TierKind::A100, 1)]);
        assert_eq!(tiers, vec![TierKind::H100, TierKind::H100, TierKind::A100]);
        assert!(validate_tiers(&[(TierKind::H100, 2), (TierKind::A100, 1)], 3).is_ok());
        assert!(validate_tiers(&[(TierKind::H100, 2)], 3).is_err());
    }

    fn snap(loads_ewmas: &[(u64, f64)]) -> ClusterSnapshot {
        ClusterSnapshot {
            now_s: 0.0,
            replicas: loads_ewmas
                .iter()
                .enumerate()
                .map(|(i, &(load, ewma))| {
                    let mut t = ReplicaTelemetry::idle(i);
                    t.load_cost = load;
                    t.step_ewma_s = ewma;
                    t
                })
                .collect(),
        }
    }

    #[test]
    fn reweight_turns_backlog_into_drain_time() {
        // equal backlog, 3x step-time gap: the fast replica must cost
        // less after reweighting
        let mut s = snap(&[(100, 0.003), (100, 0.009)]);
        reweight_by_speed(&mut s);
        assert!(s.replicas[0].load_cost < s.replicas[1].load_cost);
        // exact: (100+1) * ewma * 1e9 ns
        assert_eq!(s.replicas[0].load_cost, (101.0f64 * 0.003 * 1e9).round() as u64);
    }

    #[test]
    fn load_ties_break_toward_the_faster_replica() {
        let mut s = snap(&[(0, 0.009), (0, 0.003)]);
        reweight_by_speed(&mut s);
        assert!(
            s.replicas[1].load_cost < s.replicas[0].load_cost,
            "empty replicas must still be ordered by speed"
        );
    }

    #[test]
    fn cold_replicas_are_priced_pessimistically() {
        let mut s = snap(&[(10, 0.0), (10, 0.004), (10, 0.002)]);
        reweight_by_speed(&mut s);
        // cold replica 0 gets the slowest observed EWMA (0.004)
        assert_eq!(s.replicas[0].load_cost, s.replicas[1].load_cost);
    }

    #[test]
    fn no_history_anywhere_is_a_noop() {
        let mut s = snap(&[(7, 0.0), (3, 0.0)]);
        reweight_by_speed(&mut s);
        assert_eq!(s.replicas[0].load_cost, 7);
        assert_eq!(s.replicas[1].load_cost, 3);
    }
}
