//! Elastic control plane: admission shedding, replica autoscaling, and
//! heterogeneous hardware tiers — all pure consumers of the per-instant
//! [`ClusterSnapshot`](crate::server::telemetry::ClusterSnapshot), the
//! same telemetry surface that drives routing, the quality ladder, and
//! work stealing.
//!
//! The three pieces compose but stay independent:
//! - [`shed`] — class-aware admission shedding with SLO-relative
//!   thresholds: batch-priority traffic is dropped under pressure
//!   BEFORE the hard cap would reject interactive work, mirroring the
//!   ladder's queue-depth and projected-slack pressure signals.
//! - [`autoscale`] — a replica autoscaler over the same telemetry:
//!   scale-up on sustained slack pressure, drain-then-retire on
//!   sustained idle, with spin-up priced as expert prewarm + Stage-1
//!   table load through the residency model's host link.
//! - [`hetero`] — per-replica hardware performance tiers (mixed
//!   H100/A100 clusters) and the speed-aware load reweighting that
//!   makes every load-based decision weigh replica speed via
//!   `ReplicaTelemetry::step_ewma_s`, not just queue depth.
//!
//! Everything here defaults OFF: a cluster built without the
//! [`Cluster`](crate::server::router::Cluster) shed/autoscale/hetero
//! builders runs byte-identically to earlier releases.

pub mod autoscale;
pub mod hetero;
pub mod shed;

pub use autoscale::{warmup_cost_s, AutoscalePolicy, Autoscaler, ReplicaState, ScaleActions};
pub use hetero::{expand_tiers, hardware_for, reweight_by_speed, validate_tiers};
pub use shed::{ShedPolicy, Shedder};
