//! Two-tier expert weight store: HBM residency under a byte budget,
//! host memory behind a bandwidth/latency link.
//!
//! The store models per-(layer, expert) weight placement as a simulated,
//! measurable resource. Every demanded expert is either **resident**
//! (HBM hit, zero cost), **in flight** (a prefetch already crossing the
//! link — the demand stalls for the transfer's remaining time), or
//! **host-only** (a demand miss: the full link fetch time is charged as
//! stall). Transfers share one serial host→HBM link ([`LinkModel`])
//! whose queue drains during compute via [`ExpertStore::advance`] — that
//! overlap is what a prefetcher buys.
//!
//! Capacity is enforced in bytes: inserting past the budget evicts
//! victims chosen by the pluggable
//! [`EvictionPolicy`](super::policy::EvictionPolicy); pinned entries
//! (the k_vec-aware policy's per-layer LExI hot set) are never victims.
//! When every resident entry is pinned, an insert degrades to a bypass:
//! the weights are streamed for this access but not cached.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::policy::EvictionPolicy;

/// One expert's identity: (layer index, expert index).
pub type ExpertKey = (usize, usize);

/// Residency metadata of one HBM-resident expert.
#[derive(Clone, Copy, Debug)]
pub struct EntryMeta {
    /// Logical access clock at the last demand touch (LRU signal).
    pub last_touch: u64,
    /// Demand touches since insertion (LFU signal).
    pub touches: u64,
    /// Member of the pinned hot set: never an eviction victim.
    pub pinned: bool,
    /// Resident because a prefetch completed and no demand has arrived
    /// yet; the first demand touch counts as a prefetch hit.
    pub from_prefetch: bool,
}

/// Host→HBM transfer cost model (one serial link per replica).
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Sustained host→HBM bandwidth (B/s).
    pub bw_bytes_per_s: f64,
    /// Fixed per-transfer issue latency (s).
    pub latency_s: f64,
}

impl LinkModel {
    /// Wall time of one `bytes`-sized transfer on an idle link.
    pub fn fetch_s(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bw_bytes_per_s
    }
}

/// Outcome of one demand access.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Access {
    /// Resident in HBM; `prefetched` marks the first demand touch of an
    /// entry a prefetch brought in.
    Hit { prefetched: bool },
    /// Not resident: the access stalls for `stall_s` (remaining
    /// transfer time when the expert was already in flight, a full
    /// link fetch otherwise).
    Miss { stall_s: f64 },
}

impl Access {
    pub fn stall_s(&self) -> f64 {
        match self {
            Access::Hit { .. } => 0.0,
            Access::Miss { stall_s } => *stall_s,
        }
    }
}

/// Lifetime residency counters (per replica), reported into
/// `BackendStats` / `RunResult` and the `bench-memory` rows.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResidencyStats {
    /// Distinct demanded experts served from HBM (per step).
    pub hits: u64,
    /// Distinct demanded experts fetched over the host link.
    pub misses: u64,
    /// Prefetch transfers issued (including pin prewarms).
    pub prefetch_issued: u64,
    /// Demand touches served because a prefetch landed first.
    pub prefetch_hits: u64,
    pub evictions: u64,
    /// Demand fills dropped because every resident entry was pinned.
    pub bypasses: u64,
    /// Total stall time charged to demand misses.
    pub stall_s: f64,
    /// Per-engine-step stall percentiles (zeros included: most steps
    /// should not stall at all).
    pub stall_p50_s: f64,
    pub stall_p95_s: f64,
    /// Steps the residency model observed.
    pub steps: u64,
    pub hbm_budget_bytes: u64,
    pub hbm_used_bytes: u64,
}

impl ResidencyStats {
    /// Fraction of demanded experts served from HBM (1.0 when nothing
    /// was ever demanded).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Cluster-level aggregate: counters and stall sum; stall
    /// percentiles are step-weighted means of the per-replica values
    /// (an approximation — exact percentiles would need the raw
    /// samples); budget/used bytes sum across replicas.
    pub fn aggregate<'a>(parts: impl Iterator<Item = &'a ResidencyStats>) -> ResidencyStats {
        let mut out = ResidencyStats::default();
        let mut p50_w = 0.0;
        let mut p95_w = 0.0;
        for s in parts {
            out.hits += s.hits;
            out.misses += s.misses;
            out.prefetch_issued += s.prefetch_issued;
            out.prefetch_hits += s.prefetch_hits;
            out.evictions += s.evictions;
            out.bypasses += s.bypasses;
            out.stall_s += s.stall_s;
            out.steps += s.steps;
            out.hbm_budget_bytes += s.hbm_budget_bytes;
            out.hbm_used_bytes += s.hbm_used_bytes;
            p50_w += s.stall_p50_s * s.steps as f64;
            p95_w += s.stall_p95_s * s.steps as f64;
        }
        if out.steps > 0 {
            out.stall_p50_s = p50_w / out.steps as f64;
            out.stall_p95_s = p95_w / out.steps as f64;
        }
        out
    }
}

/// The tiered expert store of one replica.
#[derive(Debug)]
pub struct ExpertStore {
    pub hbm_budget_bytes: u64,
    /// Per-GPU bytes of one expert's weight shard.
    pub expert_bytes: u64,
    pub link: LinkModel,
    resident: BTreeMap<ExpertKey, EntryMeta>,
    /// Serial link queue: (key, remaining transfer seconds), FIFO.
    inflight: VecDeque<(ExpertKey, f64)>,
    policy: Box<dyn EvictionPolicy>,
    pins: BTreeSet<ExpertKey>,
    /// Logical demand-access clock (LRU recency).
    clock: u64,
    // ---- counters ----
    pub hits: u64,
    pub misses: u64,
    pub prefetch_issued: u64,
    pub prefetch_hits: u64,
    pub evictions: u64,
    pub bypasses: u64,
    pub stall_s: f64,
}

impl ExpertStore {
    pub fn new(
        hbm_budget_bytes: u64,
        expert_bytes: u64,
        link: LinkModel,
        policy: Box<dyn EvictionPolicy>,
    ) -> Self {
        assert!(expert_bytes > 0, "expert_bytes must be positive");
        ExpertStore {
            hbm_budget_bytes,
            expert_bytes,
            link,
            resident: BTreeMap::new(),
            inflight: VecDeque::new(),
            policy,
            pins: BTreeSet::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            prefetch_issued: 0,
            prefetch_hits: 0,
            evictions: 0,
            bypasses: 0,
            stall_s: 0.0,
        }
    }

    pub fn policy_label(&self) -> &'static str {
        self.policy.label()
    }

    /// Whether the active policy pins the per-layer LExI hot set.
    pub fn policy_pins(&self) -> bool {
        self.policy.pins_hot_set()
    }

    pub fn used_bytes(&self) -> u64 {
        self.resident.len() as u64 * self.expert_bytes
    }

    pub fn is_resident(&self, key: ExpertKey) -> bool {
        self.resident.contains_key(&key)
    }

    pub fn is_inflight(&self, key: ExpertKey) -> bool {
        self.inflight.iter().any(|(k, _)| *k == key)
    }

    /// Replace the pinned hot set. Already-resident pins are retained;
    /// returns the pinned keys that are neither resident nor in flight —
    /// the prewarm set the caller should prefetch.
    pub fn set_pins(&mut self, pins: BTreeSet<ExpertKey>) -> Vec<ExpertKey> {
        for (key, meta) in self.resident.iter_mut() {
            meta.pinned = pins.contains(key);
        }
        let missing: Vec<ExpertKey> = pins
            .iter()
            .copied()
            .filter(|k| !self.resident.contains_key(k) && !self.is_inflight(*k))
            .collect();
        self.pins = pins;
        missing
    }

    /// One demand access. Hits are free; a key in flight stalls for the
    /// link queue up to and including its transfer (which completes
    /// now); a cold key pays a full demand fetch and is inserted.
    pub fn touch(&mut self, key: ExpertKey) -> Access {
        self.clock += 1;
        if let Some(meta) = self.resident.get_mut(&key) {
            meta.last_touch = self.clock;
            meta.touches += 1;
            let prefetched = meta.from_prefetch;
            meta.from_prefetch = false;
            self.hits += 1;
            if prefetched {
                self.prefetch_hits += 1;
            }
            return Access::Hit { prefetched };
        }
        if let Some(pos) = self.inflight.iter().position(|(k, _)| *k == key) {
            // stall until the serial link delivers it (everything queued
            // ahead finishes first)
            let mut stall = 0.0;
            for _ in 0..=pos {
                let (k, remaining) = self.inflight.pop_front().unwrap();
                stall += remaining;
                self.complete_transfer(k);
            }
            // the demanded key just landed: count the demand, not a
            // prefetch hit (the prefetch was late)
            if let Some(meta) = self.resident.get_mut(&key) {
                meta.last_touch = self.clock;
                meta.touches = 1;
                meta.from_prefetch = false;
            }
            self.misses += 1;
            self.stall_s += stall;
            return Access::Miss { stall_s: stall };
        }
        // cold: demand fetch over the link, bypassing the prefetch queue
        let stall = self.link.fetch_s(self.expert_bytes);
        self.misses += 1;
        self.stall_s += stall;
        if self.insert(key) {
            let meta = self.resident.get_mut(&key).unwrap();
            meta.last_touch = self.clock;
            meta.touches = 1;
            meta.from_prefetch = false;
        }
        Access::Miss { stall_s: stall }
    }

    /// Queue a background transfer for `key` (no-op when resident or
    /// already in flight). Returns whether a transfer was issued.
    pub fn prefetch(&mut self, key: ExpertKey) -> bool {
        if self.resident.contains_key(&key) || self.is_inflight(key) {
            return false;
        }
        self.inflight.push_back((key, self.link.fetch_s(self.expert_bytes)));
        self.prefetch_issued += 1;
        true
    }

    /// Drain the link queue by `dt` seconds of overlapped compute,
    /// completing transfers in FIFO order.
    pub fn advance(&mut self, mut dt: f64) {
        while dt > 0.0 {
            let Some((_, remaining)) = self.inflight.front_mut() else { return };
            if *remaining > dt {
                *remaining -= dt;
                return;
            }
            dt -= *remaining;
            let (key, _) = self.inflight.pop_front().unwrap();
            self.complete_transfer(key);
        }
    }

    /// A finished transfer lands in HBM (evicting if needed); dropped
    /// when every resident entry is pinned and the budget is full.
    fn complete_transfer(&mut self, key: ExpertKey) {
        if self.insert(key) {
            let pinned = self.pins.contains(&key);
            let meta = self.resident.get_mut(&key).unwrap();
            meta.from_prefetch = true;
            meta.pinned = pinned;
        }
    }

    /// Make room and insert `key`; false = bypass (not cached).
    fn insert(&mut self, key: ExpertKey) -> bool {
        if self.resident.contains_key(&key) {
            return true;
        }
        if self.expert_bytes > self.hbm_budget_bytes {
            self.bypasses += 1;
            return false;
        }
        while self.used_bytes() + self.expert_bytes > self.hbm_budget_bytes {
            match self.policy.victim(&self.resident) {
                Some(victim) => {
                    self.resident.remove(&victim);
                    self.evictions += 1;
                }
                None => {
                    self.bypasses += 1;
                    return false;
                }
            }
        }
        self.resident.insert(
            key,
            EntryMeta {
                last_touch: self.clock,
                touches: 0,
                pinned: self.pins.contains(&key),
                from_prefetch: false,
            },
        );
        true
    }
}

#[cfg(test)]
mod tests {
    use super::super::policy::{Lfu, Lru};
    use super::*;

    fn link() -> LinkModel {
        LinkModel {
            bw_bytes_per_s: 1e6,
            latency_s: 1e-3,
        }
    }

    fn store(budget_experts: u64, policy: Box<dyn EvictionPolicy>) -> ExpertStore {
        ExpertStore::new(budget_experts * 1000, 1000, link(), policy)
    }

    #[test]
    fn miss_then_hit_with_lru_eviction_order() {
        let mut s = store(2, Box::new(Lru));
        // two cold fetches fill the store
        assert!(matches!(s.touch((0, 0)), Access::Miss { .. }));
        assert!(matches!(s.touch((0, 1)), Access::Miss { .. }));
        assert_eq!(s.touch((0, 0)), Access::Hit { prefetched: false });
        // third expert evicts the LRU victim (0,1)
        assert!(matches!(s.touch((0, 2)), Access::Miss { .. }));
        assert!(s.is_resident((0, 0)) && s.is_resident((0, 2)));
        assert!(!s.is_resident((0, 1)));
        assert_eq!(s.evictions, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 3);
        // stall = latency + bytes/bw per cold miss
        let per = 1e-3 + 1000.0 / 1e6;
        assert!((s.stall_s - 3.0 * per).abs() < 1e-12);
    }

    #[test]
    fn lfu_keeps_the_frequently_touched_expert() {
        let mut s = store(2, Box::new(Lfu));
        s.touch((0, 0));
        s.touch((0, 0));
        s.touch((0, 0));
        s.touch((0, 1)); // 1 touch: the LFU victim despite being fresher
        s.touch((0, 2));
        assert!(s.is_resident((0, 0)));
        assert!(!s.is_resident((0, 1)));
    }

    #[test]
    fn prefetch_overlap_turns_misses_into_hits() {
        let mut s = store(4, Box::new(Lru));
        assert!(s.prefetch((1, 0)));
        assert!(!s.prefetch((1, 0)), "duplicate prefetch issued");
        // full overlap: the transfer completes before the demand
        s.advance(1.0);
        assert_eq!(s.touch((1, 0)), Access::Hit { prefetched: true });
        assert_eq!(s.prefetch_hits, 1);

        // partial overlap: the demand stalls only for the remainder
        assert!(s.prefetch((1, 1)));
        let full = s.link.fetch_s(1000);
        s.advance(full / 2.0);
        match s.touch((1, 1)) {
            Access::Miss { stall_s } => assert!((stall_s - full / 2.0).abs() < 1e-12),
            other => panic!("expected a late-prefetch miss, got {other:?}"),
        }
        // a second touch is a plain hit, not a prefetch hit
        assert_eq!(s.touch((1, 1)), Access::Hit { prefetched: false });
        assert_eq!(s.prefetch_hits, 1);
    }

    #[test]
    fn inflight_queue_is_serial() {
        let mut s = store(4, Box::new(Lru));
        s.prefetch((0, 0));
        s.prefetch((0, 1));
        let full = s.link.fetch_s(1000);
        // demanding the SECOND queued transfer pays for both
        match s.touch((0, 1)) {
            Access::Miss { stall_s } => assert!((stall_s - 2.0 * full).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
        // the first transfer completed along the way
        assert!(s.is_resident((0, 0)));
        assert_eq!(s.touch((0, 0)), Access::Hit { prefetched: true });
    }

    #[test]
    fn pins_are_never_evicted_and_full_pinned_store_bypasses() {
        let mut s = store(2, Box::new(Lru));
        let prewarm = s.set_pins([(0, 0), (0, 1)].into_iter().collect());
        assert_eq!(prewarm, vec![(0, 0), (0, 1)]);
        for k in prewarm {
            s.prefetch(k);
        }
        s.advance(10.0);
        assert!(s.is_resident((0, 0)) && s.is_resident((0, 1)));
        // every slot pinned: a new expert streams through without caching
        assert!(matches!(s.touch((2, 0)), Access::Miss { .. }));
        assert!(!s.is_resident((2, 0)));
        assert_eq!(s.bypasses, 1);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.touch((0, 0)), Access::Hit { prefetched: true });
        // unpinning frees the entries for eviction again
        let missing = s.set_pins(BTreeSet::new());
        assert!(missing.is_empty());
        s.touch((2, 0));
        assert!(s.is_resident((2, 0)));
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn budget_smaller_than_one_expert_always_bypasses() {
        let mut s = ExpertStore::new(10, 1000, link(), Box::new(Lru));
        assert!(matches!(s.touch((0, 0)), Access::Miss { .. }));
        assert!(!s.is_resident((0, 0)));
        assert_eq!(s.used_bytes(), 0);
        assert_eq!(s.bypasses, 1);
    }
}
