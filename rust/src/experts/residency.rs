//! Per-replica residency simulation: one [`ExpertResidency`] drives the
//! tiered store through every engine scheduling step.
//!
//! Each step walks the layers in execution order. For layer `j` it
//! samples the demanded expert set from the layer's routing popularity
//! (the same Monte-Carlo the perf model uses, seeded per replica),
//! touches each demanded expert in the store (accumulating stall on
//! misses), issues predictive prefetches for layer `j+1`, and advances
//! the host→HBM link by the layer's share of the step's compute time —
//! the overlap window prefetch lives in.
//!
//! The per-layer active budget is the live `k_vec`, so LExI's
//! layer-adaptive allocations shrink demand (and pinned hot sets) per
//! layer; quality-ladder rung switches call
//! [`ExpertResidency::set_k_vec`], which repins and prewarms the new hot
//! set.

use std::collections::BTreeSet;

use crate::config::model::ModelSpec;
use crate::config::server::EvictKind;
use crate::moe::arch::ModelGeom;
use crate::moe::routing::RoutingSim;
use crate::perfmodel::loadbalance::LayerRouting;
use crate::perfmodel::Hardware;
use crate::util::stats::percentile;
use crate::util::Pcg32;

use super::prefetch::Prefetcher;
use super::store::{ExpertKey, ExpertStore, LinkModel, ResidencyStats};

/// Fraction of the HBM budget the k_vec-aware policy may pin; the rest
/// stays a general-purpose pool so tail experts are still cacheable.
const PIN_BUDGET_FRAC: f64 = 0.9;

/// Declarative knobs of one replica's residency model.
#[derive(Clone, Debug)]
pub struct ResidencyConfig {
    /// HBM bytes available for expert weights (per GPU).
    pub hbm_budget_bytes: u64,
    /// Per-GPU bytes of one expert's weight shard.
    pub expert_bytes: u64,
    pub n_layers: usize,
    pub n_experts: usize,
    pub policy: EvictKind,
    /// Enable predictive prefetch (pin prewarm stays on either way).
    pub prefetch: bool,
    /// Prefetcher depth cap (experts per layer transition).
    pub prefetch_depth: usize,
    /// Prefetcher cumulative-mass target.
    pub prefetch_mass: f64,
    pub link: LinkModel,
    /// Nominal compute time per engine step available to overlap
    /// transfers (split evenly across layers).
    pub overlap_s_per_step: f64,
    /// Cap on tokens fed to the per-layer routing Monte-Carlo (bounds
    /// prefill-step cost; the distinct-expert set saturates quickly).
    pub sim_tokens_cap: usize,
    /// Routing + demand-sampling seed (routing is shared across
    /// replicas of one model; the demand stream varies per replica).
    pub seed: u64,
}

impl ResidencyConfig {
    /// Residency model for a registry model at paper scale: expert shard
    /// bytes from the model geometry, link constants from the hardware
    /// model, budget as a fraction of the full expert footprint.
    pub fn for_model(spec: &ModelSpec, budget_frac: f64, policy: EvictKind, seed: u64) -> Self {
        let geom = ModelGeom::paper_scale(spec);
        let hw = Hardware::h100();
        let expert_bytes =
            (geom.layer.expert_weight_bytes(hw.dtype_bytes) / spec.paper.n_gpus as f64) as u64;
        Self::for_dims(spec.n_layers, spec.n_experts, expert_bytes, budget_frac, policy, seed)
    }

    /// Residency model over explicit dimensions (engine-backed replicas
    /// use the compiled graph's layer/expert counts).
    pub fn for_dims(
        n_layers: usize,
        n_experts: usize,
        expert_bytes: u64,
        budget_frac: f64,
        policy: EvictKind,
        seed: u64,
    ) -> Self {
        assert!(budget_frac > 0.0, "HBM budget fraction must be positive");
        let hw = Hardware::h100();
        let total = (n_layers * n_experts) as u64 * expert_bytes.max(1);
        ResidencyConfig {
            hbm_budget_bytes: (total as f64 * budget_frac.min(1.0)) as u64,
            expert_bytes: expert_bytes.max(1),
            n_layers,
            n_experts,
            policy,
            prefetch: true,
            prefetch_depth: 4,
            prefetch_mass: 0.9,
            link: LinkModel {
                bw_bytes_per_s: hw.host_link_bw,
                latency_s: hw.host_link_latency,
            },
            overlap_s_per_step: 2e-3,
            sim_tokens_cap: 64,
            seed,
        }
    }
}

/// What one engine step cost the residency model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepResidency {
    pub stall_s: f64,
    pub hits: u64,
    pub misses: u64,
    pub prefetch_hits: u64,
}

/// One replica's residency simulation (store + predictor + routing).
#[derive(Debug)]
pub struct ExpertResidency {
    store: ExpertStore,
    prefetcher: Option<Prefetcher>,
    routing: Vec<RoutingSim>,
    /// Per-layer expert indices by descending popularity, computed once
    /// (routing is immutable here; prediction and pinning run per step).
    pop_order: Vec<Vec<usize>>,
    k_vec: Vec<i32>,
    overlap_s: f64,
    tokens_cap: usize,
    rng: Pcg32,
    steps: u64,
    stall_samples_s: Vec<f64>,
    /// EWMA of the per-step demand miss rate — the telemetry pressure
    /// signal (0 = everything resident, 1 = every access faults).
    miss_ewma: f64,
}

impl ExpertResidency {
    /// Build with the model's synthetic per-layer routing (shared with
    /// the perf model for the same seed). `replica` decorrelates the
    /// demand-sampling stream across replicas.
    pub fn new(cfg: &ResidencyConfig, k_vec: Vec<i32>, replica: u64) -> Self {
        let routing = LayerRouting::synthetic(cfg.n_layers, cfg.n_experts, cfg.seed).sims;
        Self::with_routing(cfg, k_vec, replica, routing)
    }

    /// Build over caller-supplied routing (tests, measured calibration).
    pub fn with_routing(
        cfg: &ResidencyConfig,
        k_vec: Vec<i32>,
        replica: u64,
        routing: Vec<RoutingSim>,
    ) -> Self {
        assert_eq!(k_vec.len(), cfg.n_layers, "k_vec length != layer count");
        assert_eq!(routing.len(), cfg.n_layers, "routing length != layer count");
        for sim in &routing {
            assert_eq!(sim.n_experts(), cfg.n_experts, "routing width != expert count");
        }
        let store = ExpertStore::new(
            cfg.hbm_budget_bytes,
            cfg.expert_bytes,
            cfg.link,
            cfg.policy.build(),
        );
        let prefetcher = cfg
            .prefetch
            .then(|| Prefetcher::new(cfg.prefetch_depth, cfg.prefetch_mass));
        let pop_order: Vec<Vec<usize>> = routing.iter().map(|s| s.by_popularity()).collect();
        let mut r = ExpertResidency {
            store,
            prefetcher,
            routing,
            pop_order,
            k_vec,
            overlap_s: cfg.overlap_s_per_step,
            tokens_cap: cfg.sim_tokens_cap.max(1),
            rng: Pcg32::new(cfg.seed, 0xe59e_2026 ^ replica),
            steps: 0,
            stall_samples_s: Vec::new(),
            miss_ewma: 0.0,
        };
        r.repin_and_prewarm();
        r
    }

    pub fn n_layers(&self) -> usize {
        self.routing.len()
    }

    pub fn policy_label(&self) -> &'static str {
        self.store.policy_label()
    }

    /// Active per-layer budget for layer `j`, clamped to the router's
    /// selectable expert count.
    fn k_at(&self, j: usize) -> usize {
        let selectable = self.routing[j]
            .popularity
            .iter()
            .filter(|&&p| p > 0.0)
            .count()
            .max(1);
        (self.k_vec[j].max(1) as usize).min(selectable)
    }

    /// The pinned LExI hot set in priority order: rank-major across
    /// layers (every layer's top-1 before any layer's top-2), capped at
    /// [`PIN_BUDGET_FRAC`] of the HBM budget so a general pool remains.
    fn pin_order(&self) -> Vec<ExpertKey> {
        let cap = ((self.store.hbm_budget_bytes as f64 * PIN_BUDGET_FRAC)
            / self.store.expert_bytes as f64) as usize;
        let max_k = (0..self.routing.len()).map(|j| self.k_at(j)).max().unwrap_or(0);
        let mut pins = Vec::new();
        'ranks: for rank in 0..max_k {
            for (j, order) in self.pop_order.iter().enumerate() {
                if rank >= self.k_at(j) {
                    continue;
                }
                if pins.len() >= cap {
                    break 'ranks;
                }
                pins.push((j, order[rank]));
            }
        }
        pins
    }

    /// Recompute pins for the current `k_vec` and prewarm the missing
    /// ones over the link (most popular first). No-op for policies that
    /// do not pin.
    fn repin_and_prewarm(&mut self) {
        if !self.store.policy_pins() {
            return;
        }
        let order = self.pin_order();
        self.store.set_pins(order.iter().copied().collect::<BTreeSet<_>>());
        for key in order {
            self.store.prefetch(key);
        }
    }

    /// Swap the live per-layer budgets (quality-ladder rung switch):
    /// the k_vec-aware pinned set is invalidated and the new hot set
    /// prewarmed.
    pub fn set_k_vec(&mut self, k_vec: &[i32]) {
        assert_eq!(k_vec.len(), self.routing.len(), "k_vec length != layer count");
        self.k_vec = k_vec.to_vec();
        self.repin_and_prewarm();
    }

    /// One engine scheduling step over `tokens` routed tokens (active
    /// decode slots, or the admitted prompt tokens of a prefill).
    pub fn step(&mut self, tokens: usize) -> StepResidency {
        crate::prof_scope!("residency.step");
        let (h0, m0, p0) = (self.store.hits, self.store.misses, self.store.prefetch_hits);
        let mut stall = 0.0;
        let l = self.routing.len();
        let per_layer_overlap = self.overlap_s / l as f64;
        let tokens = tokens.clamp(1, self.tokens_cap);
        for j in 0..l {
            let k = self.k_at(j);
            let loads = self.routing[j].sample_loads(tokens, k, &mut self.rng);
            for (e, &load) in loads.iter().enumerate() {
                if load > 0 {
                    stall += self.store.touch((j, e)).stall_s();
                }
            }
            if let Some(p) = self.prefetcher {
                let nxt = (j + 1) % l;
                let predicted = p.predict_from(
                    &self.routing[nxt].popularity,
                    &self.pop_order[nxt],
                    self.k_at(nxt),
                );
                for e in predicted {
                    self.store.prefetch((nxt, e));
                }
            }
            self.store.advance(per_layer_overlap);
        }
        self.steps += 1;
        self.stall_samples_s.push(stall);
        let out = StepResidency {
            stall_s: stall,
            hits: self.store.hits - h0,
            misses: self.store.misses - m0,
            prefetch_hits: self.store.prefetch_hits - p0,
        };
        let touched = out.hits + out.misses;
        if touched > 0 {
            let inst = out.misses as f64 / touched as f64;
            self.miss_ewma = if self.steps == 1 {
                inst
            } else {
                0.2 * inst + 0.8 * self.miss_ewma
            };
        }
        out
    }

    /// Residency pressure in [0, 1]: EWMA of the per-step demand miss
    /// rate (the telemetry signal).
    pub fn pressure(&self) -> f64 {
        self.miss_ewma
    }

    /// Lifetime counters + per-step stall percentiles.
    pub fn stats(&self) -> ResidencyStats {
        ResidencyStats {
            hits: self.store.hits,
            misses: self.store.misses,
            prefetch_issued: self.store.prefetch_issued,
            prefetch_hits: self.store.prefetch_hits,
            evictions: self.store.evictions,
            bypasses: self.store.bypasses,
            stall_s: self.store.stall_s,
            stall_p50_s: percentile(&self.stall_samples_s, 50.0),
            stall_p95_s: percentile(&self.stall_samples_s, 95.0),
            steps: self.steps,
            hbm_budget_bytes: self.store.hbm_budget_bytes,
            hbm_used_bytes: self.store.used_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(budget_frac: f64, policy: EvictKind, prefetch: bool) -> ResidencyConfig {
        let mut c = ResidencyConfig::for_dims(4, 16, 1 << 20, budget_frac, policy, 7);
        c.prefetch = prefetch;
        // slow link + short overlap so residency effects are visible
        c.link = LinkModel {
            bw_bytes_per_s: 2e8,
            latency_s: 1e-4,
        };
        c.overlap_s_per_step = 4e-3;
        c
    }

    fn residency(budget_frac: f64, policy: EvictKind, prefetch: bool) -> ExpertResidency {
        ExpertResidency::new(&cfg(budget_frac, policy, prefetch), vec![2; 4], 0)
    }

    #[test]
    fn kvec_policy_pins_and_prewarms_the_hot_set() {
        let mut r = residency(0.5, EvictKind::KvecAware, false);
        // prewarm transfers were issued for every pin
        assert!(r.stats().prefetch_issued > 0);
        // after enough overlap the hot set is resident: touching the
        // most popular experts of each layer must hit
        for _ in 0..64 {
            r.step(4);
        }
        let warm = r.stats();
        assert!(warm.hit_rate() > 0.0);
        let top: Vec<ExpertKey> = (0..4).map(|j| (j, r.routing[j].by_popularity()[0])).collect();
        for key in top {
            assert!(r.store.is_resident(key), "{key:?} not pinned-resident");
        }
    }

    #[test]
    fn rung_switch_repins_to_the_new_hot_set() {
        // 9 HBM slots: 8 pinned (0.9 cap), 1 general slot — so after the
        // switch at most one of the newly pinned experts can already be
        // resident and prewarm traffic is guaranteed
        let mut r = residency(9.0 / 64.0, EvictKind::KvecAware, false);
        for _ in 0..32 {
            r.step(4);
        }
        let issued_before = r.stats().prefetch_issued;
        r.set_k_vec(&[4, 4, 1, 1]);
        // deeper front layers pin more experts -> new prewarm traffic
        assert!(r.stats().prefetch_issued > issued_before);
        assert_eq!(r.k_at(0), 4);
        assert_eq!(r.k_at(2), 1);
    }

    #[test]
    fn pressure_stays_normalized_and_tracks_misses() {
        let mut r = residency(0.1, EvictKind::Lru, false);
        for _ in 0..32 {
            r.step(8);
        }
        let p = r.pressure();
        assert!((0.0..=1.0).contains(&p), "pressure {p}");
        // a 10% budget on 64 experts must fault regularly
        assert!(p > 0.0);
        let mut full = residency(1.0, EvictKind::Lru, false);
        for _ in 0..32 {
            full.step(8);
        }
        assert!(full.pressure() < p);
    }

    #[test]
    fn full_budget_stops_missing_after_warmup() {
        let mut r = residency(1.0, EvictKind::Lru, false);
        for _ in 0..128 {
            r.step(8);
        }
        let s = r.stats();
        assert_eq!(s.evictions, 0);
        // at most one cold miss per (layer, expert)
        assert!(s.misses <= 64);
        assert!(s.hit_rate() > 0.9);
    }
}
