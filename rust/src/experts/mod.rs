//! Expert residency subsystem: tiered HBM/host expert weight placement
//! as a first-class, simulated serving resource.
//!
//! LExI's layer-adaptive `k_vec` shrinks each layer's *active* expert
//! set, but every expert's weights still have to live somewhere. This
//! module models that somewhere: an [`ExpertStore`] holds per-(layer,
//! expert) weight shards across two tiers — HBM under a byte budget and
//! host memory behind a bandwidth/latency [`LinkModel`] — with
//! pluggable eviction ([`policy`]: LRU, LFU, and a k_vec-aware policy
//! that pins each layer's LExI hot set), a predictive [`Prefetcher`]
//! that forecasts next-layer demand from routing popularity, and a
//! per-step driver ([`ExpertResidency`]) that charges demand-miss stall
//! time into whatever is driving it.
//!
//! Consumers:
//! - `engine::Engine` steps the model once per scheduling step and
//!   surfaces hit/miss/stall counters in `EngineMetrics`.
//! - `server::Replica` / `server::EngineReplica` add stall to phase
//!   durations, report [`ResidencyStats`] per replica, and repin on
//!   quality-ladder rung switches.
//! - `perfmodel::PerfModel` has the analytical twin: an expert-traffic
//!   term under an HBM budget (`with_hbm_budget_bytes`).
//! - `lexi bench-memory` sweeps HBM budgets x eviction policies.
//!
//! Module map:
//! - [`store`]     — two-tier store, link cost model, stats
//! - [`policy`]    — eviction policies (`EvictKind::build`)
//! - [`prefetch`]  — popularity-driven demand prediction
//! - [`residency`] — the per-step driver + configuration

pub mod policy;
pub mod prefetch;
pub mod residency;
pub mod store;

pub use policy::{EvictionPolicy, KvecAware, Lfu, Lru};
pub use prefetch::Prefetcher;
pub use residency::{ExpertResidency, ResidencyConfig, StepResidency};
pub use store::{Access, ExpertKey, ExpertStore, LinkModel, ResidencyStats};
