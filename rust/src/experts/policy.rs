//! Pluggable HBM eviction policies for the expert store.
//!
//! A policy only chooses victims; residency metadata (recency clock,
//! touch counts, pin flags) lives in the
//! [`ExpertStore`](super::store::ExpertStore) so every policy reads the
//! same signals. Pinned entries (the k_vec-aware policy's per-layer
//! LExI hot set) are excluded from the victim set by contract.

use std::collections::BTreeMap;

use crate::config::server::EvictKind;

use super::store::{EntryMeta, ExpertKey};

/// Victim selection over the resident set.
pub trait EvictionPolicy: std::fmt::Debug {
    fn label(&self) -> &'static str;

    /// Next eviction victim among resident, non-pinned entries (`None`
    /// when everything resident is pinned).
    fn victim(&self, resident: &BTreeMap<ExpertKey, EntryMeta>) -> Option<ExpertKey>;

    /// Whether the store should pin the per-layer LExI hot set for this
    /// policy (recomputed on every `k_vec` swap).
    fn pins_hot_set(&self) -> bool {
        false
    }
}

/// Select the non-pinned entry minimizing `rank` (ties break by key, so
/// victim choice is a deterministic total order).
fn argmin_by<R: Ord>(
    resident: &BTreeMap<ExpertKey, EntryMeta>,
    rank: impl Fn(&EntryMeta) -> R,
) -> Option<ExpertKey> {
    resident
        .iter()
        .filter(|(_, m)| !m.pinned)
        .min_by(|(ka, ma), (kb, mb)| rank(ma).cmp(&rank(mb)).then(ka.cmp(kb)))
        .map(|(k, _)| *k)
}

/// Evict the least-recently demanded expert.
#[derive(Clone, Copy, Debug, Default)]
pub struct Lru;

impl EvictionPolicy for Lru {
    fn label(&self) -> &'static str {
        "lru"
    }

    fn victim(&self, resident: &BTreeMap<ExpertKey, EntryMeta>) -> Option<ExpertKey> {
        argmin_by(resident, |m| m.last_touch)
    }
}

/// Evict the least-frequently demanded expert (recency breaks ties, so
/// an untouched prefetch goes before an old-but-used entry).
#[derive(Clone, Copy, Debug, Default)]
pub struct Lfu;

impl EvictionPolicy for Lfu {
    fn label(&self) -> &'static str {
        "lfu"
    }

    fn victim(&self, resident: &BTreeMap<ExpertKey, EntryMeta>) -> Option<ExpertKey> {
        argmin_by(resident, |m| (m.touches, m.last_touch))
    }
}

/// LExI-aware policy: the store pins each layer's top-`k_vec[j]` experts
/// by routing popularity (the hot set the active-expert budget actually
/// routes to), and the remaining capacity falls back to LRU. Rung
/// switches repin — the mechanism behind prewarm-on-upgrade.
#[derive(Clone, Copy, Debug, Default)]
pub struct KvecAware;

impl EvictionPolicy for KvecAware {
    fn label(&self) -> &'static str {
        "kvec"
    }

    fn victim(&self, resident: &BTreeMap<ExpertKey, EntryMeta>) -> Option<ExpertKey> {
        argmin_by(resident, |m| m.last_touch)
    }

    fn pins_hot_set(&self) -> bool {
        true
    }
}

impl EvictKind {
    /// Instantiate the eviction-policy implementation for this kind
    /// (mirrors `PolicyKind::build` for routing policies).
    pub fn build(self) -> Box<dyn EvictionPolicy> {
        match self {
            EvictKind::Lru => Box::new(Lru),
            EvictKind::Lfu => Box::new(Lfu),
            EvictKind::KvecAware => Box::new(KvecAware),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(last_touch: u64, touches: u64, pinned: bool) -> EntryMeta {
        EntryMeta {
            last_touch,
            touches,
            pinned,
            from_prefetch: false,
        }
    }

    #[test]
    fn lru_and_lfu_pick_different_victims() {
        let mut resident = BTreeMap::new();
        resident.insert((0, 0), meta(10, 1, false)); // fresh, rarely used
        resident.insert((0, 1), meta(2, 9, false)); // old, heavily used
        assert_eq!(Lru.victim(&resident), Some((0, 1)));
        assert_eq!(Lfu.victim(&resident), Some((0, 0)));
    }

    #[test]
    fn pinned_entries_are_never_victims() {
        let mut resident = BTreeMap::new();
        resident.insert((0, 0), meta(1, 1, true));
        resident.insert((0, 1), meta(5, 5, true));
        for kind in [EvictKind::Lru, EvictKind::Lfu, EvictKind::KvecAware] {
            assert_eq!(kind.build().victim(&resident), None, "{kind:?}");
        }
        resident.insert((1, 0), meta(100, 100, false));
        assert_eq!(Lru.victim(&resident), Some((1, 0)));
    }

    #[test]
    fn build_matches_labels_and_pin_behavior() {
        assert_eq!(EvictKind::Lru.build().label(), "lru");
        assert_eq!(EvictKind::Lfu.build().label(), "lfu");
        let kv = EvictKind::KvecAware.build();
        assert_eq!(kv.label(), "kvec");
        assert!(kv.pins_hot_set());
        assert!(!EvictKind::Lru.build().pins_hot_set());
    }
}
