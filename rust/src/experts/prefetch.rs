//! Predictive expert prefetch: per-layer routing frequencies forecast
//! the next layer's demand, and transfers overlap with the current
//! layer's compute.
//!
//! The predictor is the same signal LExI Stage 1 profiles — the routing
//! popularity of [`RoutingSim`] — read through
//! [`RoutingSim::top_p_mass`]/[`RoutingSim::by_popularity`]: fetch the
//! most popular experts of layer `j+1` until the predicted cumulative
//! routing mass reaches `mass_target` (never fewer than the layer's
//! active budget `k_j+1`, never more than `depth`). Skewed routers
//! concentrate mass in a few experts, so a shallow prefetch covers most
//! of next layer's demand; a uniform router defeats prediction — exactly
//! the stall-vs-prefetch tradeoff studied in the predictive-prefetching
//! literature.

use crate::moe::routing::RoutingSim;

/// Demand predictor for one replica's expert stream.
#[derive(Clone, Copy, Debug)]
pub struct Prefetcher {
    /// Hard cap on experts prefetched per layer transition.
    pub depth: usize,
    /// Stop once the predicted experts cover this much routing mass.
    pub mass_target: f64,
}

impl Default for Prefetcher {
    fn default() -> Self {
        Prefetcher {
            depth: 4,
            mass_target: 0.9,
        }
    }
}

impl Prefetcher {
    pub fn new(depth: usize, mass_target: f64) -> Self {
        Prefetcher { depth, mass_target }
    }

    /// Predicted expert set for a layer routing `k` active experts per
    /// token: most-popular-first, at least `min(k, depth)` entries,
    /// stopping at `mass_target` cumulative mass or `depth` experts.
    pub fn predict(&self, sim: &RoutingSim, k: usize) -> Vec<usize> {
        self.predict_from(&sim.popularity, &sim.by_popularity(), k)
    }

    /// [`Prefetcher::predict`] over a precomputed popularity order —
    /// the hot path: callers that predict every layer every step cache
    /// `RoutingSim::by_popularity` once instead of re-sorting.
    pub fn predict_from(&self, popularity: &[f64], order: &[usize], k: usize) -> Vec<usize> {
        let floor = k.min(self.depth).max(1);
        let mut out = Vec::with_capacity(self.depth.max(1));
        let mut mass = 0.0;
        for &e in order {
            if out.len() >= self.depth.max(1) {
                break;
            }
            if out.len() >= floor && mass >= self.mass_target {
                break;
            }
            mass += popularity[e];
            out.push(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_needs_fewer_prefetches_than_uniform() {
        let skew = RoutingSim::from_frequencies(&[80.0, 10.0, 4.0, 2.0, 2.0, 1.0, 0.5, 0.5]);
        let flat = RoutingSim::from_frequencies(&[1.0; 8]);
        let p = Prefetcher::new(8, 0.9);
        let from_skew = p.predict(&skew, 1);
        let from_flat = p.predict(&flat, 1);
        assert!(from_skew.len() < from_flat.len());
        // most popular expert always leads the prediction
        assert_eq!(from_skew[0], 0);
        // depth caps the uniform case
        assert_eq!(from_flat.len(), 8);
    }

    #[test]
    fn prediction_covers_at_least_the_active_budget() {
        let skew = RoutingSim::from_frequencies(&[90.0, 5.0, 3.0, 1.0, 1.0]);
        let p = Prefetcher::new(4, 0.5);
        // mass target met by expert 0 alone, but k=3 forces 3 entries
        assert_eq!(p.predict(&skew, 3).len(), 3);
        // depth wins over k when they conflict
        assert_eq!(p.predict(&skew, 9).len(), 4);
    }
}
