//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so the repo ships the
//! subset of anyhow's API the codebase actually uses: [`Error`] with a
//! context chain, [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! `{e}` prints the outermost message, `{e:#}` the full chain joined by
//! ": ", and `{e:?}` an anyhow-style "Caused by" listing.

use std::fmt;

/// Error with a most-recent-first context chain.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Prepend a context layer (what `Context::context` attaches).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Outermost-first iterator over the message chain.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            $crate::bail!($($t)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42);
    }

    #[test]
    fn chain_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn std_error_converts_with_sources() {
        let r: Result<i32> = "nope".parse::<i32>().context("parsing");
        let e = r.unwrap_err();
        assert!(format!("{e:#}").starts_with("parsing: "));
    }

    #[test]
    fn ensure_and_option() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1);
            ensure!(x > 2, "x too small: {x}");
            Ok(x)
        }
        assert!(f(1).is_err());
        assert!(format!("{}", f(2).unwrap_err()).contains("x too small"));
        assert_eq!(f(3).unwrap(), 3);
        let o: Option<i32> = None;
        assert!(o.context("missing").is_err());
    }
}
