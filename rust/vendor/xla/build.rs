//! Default (stub) builds do nothing here. With `--features real`, link
//! the prebuilt XLA extension + the xla-rs C shim from
//! `$XLA_EXTENSION_DIR` (expected layout: `lib/libxla_extension.so` and
//! `lib/libxla_rs.a|so`, as produced by an xla-rs build).

fn main() {
    println!("cargo:rerun-if-env-changed=XLA_EXTENSION_DIR");
    if std::env::var_os("CARGO_FEATURE_REAL").is_none() {
        return;
    }
    let dir = match std::env::var("XLA_EXTENSION_DIR") {
        Ok(d) if !d.is_empty() => d,
        _ => panic!(
            "the `real` feature (xla-real) swaps in FFI bindings against a prebuilt \
             xla_extension; set XLA_EXTENSION_DIR to its install root \
             (containing lib/libxla_extension.* and the xla_rs C shim)"
        ),
    };
    println!("cargo:rustc-link-search=native={dir}/lib");
    println!("cargo:rustc-link-lib=dylib=xla_extension");
    println!("cargo:rustc-link-lib=dylib=xla_rs");
    println!("cargo:rustc-link-lib=dylib=stdc++");
}
