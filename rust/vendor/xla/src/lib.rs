//! Vendored API-compatible stub for the `xla-rs` PJRT bindings.
//!
//! The container has no network access and no prebuilt XLA/PJRT shared
//! library, so the real bindings cannot be fetched or linked. This stub
//! keeps the whole crate compiling and testable:
//!
//! * host-side [`Literal`] operations (create / to_vec / shapes / npz
//!   reading of uncompressed archives) are fully functional;
//! * device operations ([`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute_b`]) return a clear runtime error —
//!   everything that does NOT touch a compiled executable (perf model,
//!   LExI search over synthetic/cached tables, the serving simulator,
//!   the synthetic-model engine backend) works end-to-end.
//!
//! Opting into the **`real`** feature (crate feature `xla-real` at the
//! workspace root) swaps the stubbed device path for FFI bindings
//! against a prebuilt `xla_extension` + the xla-rs `xla_rs` C shim
//! located via `XLA_EXTENSION_DIR` (see `build.rs` / `src/real.rs`);
//! the host-side literal/npz code is shared by both modes and no call
//! site changes either way.

#[cfg(feature = "real")]
mod real;
#[cfg(feature = "real")]
pub use real::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use std::fmt;
use std::path::Path;

const STUB_MSG: &str =
    "PJRT unavailable: built against the vendored xla stub (rust/vendor/xla); \
     artifact-backed execution requires the real xla-rs bindings";

#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

// --------------------------------------------------------------------
// element types
// --------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(&self) -> usize {
        4
    }
}

/// Host-representable element types (f32 / i32 in this repo).
pub trait ArrayElement: Copy {
    const TY: ElementType;
    fn from_le_bytes(b: [u8; 4]) -> Self;
    fn to_le_bytes(self) -> [u8; 4];
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
    fn to_le_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
    fn to_le_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

// --------------------------------------------------------------------
// shapes + literals (fully functional on the host)
// --------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// A host tensor: element type + dims + little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n * ty.byte_size() != data.len() {
            return err(format!(
                "literal size mismatch: shape {dims:?} needs {} bytes, got {}",
                n * ty.byte_size(),
                data.len()
            ));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    pub fn scalar<T: ArrayElement>(v: T) -> Self {
        Literal {
            ty: T::TY,
            dims: vec![],
            bytes: v.to_le_bytes().to_vec(),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty: self.ty,
        })
    }

    pub fn element_count(&self) -> usize {
        self.bytes.len() / self.ty.byte_size()
    }

    /// Raw little-endian bytes (FFI marshalling in `real` mode).
    pub fn raw_bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return err(format!(
                "element type mismatch: literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            ));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// The stub never produces tuple literals, so there is nothing to
    /// decompose.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        err(format!("decompose_tuple: {STUB_MSG}"))
    }
}

// --------------------------------------------------------------------
// npz reading (uncompressed archives, as written by numpy.savez)
// --------------------------------------------------------------------

/// Loading literals from raw on-disk formats (the npz subset this repo
/// exchanges with the Python build step).
pub trait FromRawBytes: Sized {
    /// Read every array of an UNCOMPRESSED npz archive, returning
    /// `(name, literal)` pairs with the `.npy` suffix stripped.
    fn read_npz<P: AsRef<Path>>(path: P, opts: &()) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    fn read_npz<P: AsRef<Path>>(path: P, _opts: &()) -> Result<Vec<(String, Self)>> {
        let bytes = std::fs::read(path.as_ref())
            .map_err(|e| Error(format!("reading {:?}: {e}", path.as_ref())))?;
        read_npz_bytes(&bytes)
    }
}

fn read_u16(b: &[u8], off: usize) -> u64 {
    u16::from_le_bytes([b[off], b[off + 1]]) as u64
}

fn read_u32(b: &[u8], off: usize) -> u64 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]]) as u64
}

fn read_npz_bytes(b: &[u8]) -> Result<Vec<(String, Literal)>> {
    const LOCAL_SIG: u64 = 0x0403_4b50;
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos + 30 <= b.len() && read_u32(b, pos) == LOCAL_SIG {
        let flags = read_u16(b, pos + 6);
        let method = read_u16(b, pos + 8);
        let csize = read_u32(b, pos + 18) as usize;
        let name_len = read_u16(b, pos + 26) as usize;
        let extra_len = read_u16(b, pos + 28) as usize;
        let name_off = pos + 30;
        if name_off + name_len + extra_len > b.len() {
            return err("npz: truncated local header");
        }
        let name = String::from_utf8_lossy(&b[name_off..name_off + name_len]).into_owned();
        let data_off = name_off + name_len + extra_len;
        if method != 0 {
            return err(format!(
                "npz entry '{name}': compressed archives unsupported by the xla stub \
                 (use numpy.savez, not savez_compressed)"
            ));
        }
        if flags & 0x8 != 0 && csize == 0 {
            return err(format!("npz entry '{name}': streamed sizes unsupported"));
        }
        if data_off + csize > b.len() {
            return err(format!("npz entry '{name}': truncated data"));
        }
        let lit = parse_npy(&b[data_off..data_off + csize])
            .map_err(|e| Error(format!("npz entry '{name}': {e}")))?;
        out.push((name.trim_end_matches(".npy").to_string(), lit));
        pos = data_off + csize;
    }
    if out.is_empty() {
        return err("npz: no stored entries found (not a zip archive?)");
    }
    Ok(out)
}

fn parse_npy(b: &[u8]) -> Result<Literal> {
    if b.len() < 10 || &b[..6] != b"\x93NUMPY" {
        return err("bad npy magic");
    }
    let major = b[6];
    let (hlen, hstart) = if major == 1 {
        (read_u16(b, 8) as usize, 10)
    } else {
        if b.len() < 12 {
            return err("truncated npy header");
        }
        (read_u32(b, 8) as usize, 12)
    };
    if hstart + hlen > b.len() {
        return err("truncated npy header");
    }
    let header = String::from_utf8_lossy(&b[hstart..hstart + hlen]).into_owned();
    let descr = field_str(&header, "descr").ok_or_else(|| Error("npy: no descr".into()))?;
    let ty = match descr.as_str() {
        "<f4" => ElementType::F32,
        "<i4" => ElementType::S32,
        other => return err(format!("npy dtype '{other}' unsupported (need <f4 or <i4)")),
    };
    if header.contains("'fortran_order': True") {
        return err("npy: fortran order unsupported");
    }
    let shape = field_shape(&header).ok_or_else(|| Error("npy: no shape".into()))?;
    let n: usize = shape.iter().product();
    let data = &b[hstart + hlen..];
    if data.len() < n * 4 {
        return err(format!("npy: expected {} bytes, got {}", n * 4, data.len()));
    }
    Literal::create_from_shape_and_untyped_data(ty, &shape, &data[..n * 4])
}

/// Extract `'key': '<value>'` from an npy header dict.
fn field_str(header: &str, key: &str) -> Option<String> {
    let pat = format!("'{key}':");
    let at = header.find(&pat)? + pat.len();
    let rest = &header[at..];
    let open = rest.find('\'')? + 1;
    let close = open + rest[open..].find('\'')?;
    Some(rest[open..close].to_string())
}

/// Extract the shape tuple `(a, b, ...)` from an npy header dict.
fn field_shape(header: &str) -> Option<Vec<usize>> {
    let at = header.find("'shape':")? + "'shape':".len();
    let rest = &header[at..];
    let open = rest.find('(')? + 1;
    let close = open + rest[open..].find(')')?;
    let inner = &rest[open..close];
    let mut dims = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        dims.push(p.parse::<usize>().ok()?);
    }
    Some(dims)
}

// --------------------------------------------------------------------
// PJRT surface (stubbed device path; feature `real` swaps in FFI)
// --------------------------------------------------------------------

/// HLO module parsed from text — retained verbatim; only the real
/// bindings can lower it.
#[cfg(not(feature = "real"))]
pub struct HloModuleProto {
    pub text: String,
}

#[cfg(not(feature = "real"))]
impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("reading {:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { text })
    }
}

#[cfg(not(feature = "real"))]
pub struct XlaComputation;

#[cfg(not(feature = "real"))]
impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device buffer — in the stub, a host literal in disguise, so upload /
/// download round-trips work without a device.
#[cfg(not(feature = "real"))]
pub struct PjRtBuffer(Literal);

#[cfg(not(feature = "real"))]
impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.0.clone())
    }
}

#[cfg(not(feature = "real"))]
pub struct PjRtLoadedExecutable;

#[cfg(not(feature = "real"))]
impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(format!("execute: {STUB_MSG}"))
    }
}

#[cfg(not(feature = "real"))]
#[derive(Clone)]
pub struct PjRtClient;

#[cfg(not(feature = "real"))]
impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(format!("compile: {STUB_MSG}"))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        Ok(PjRtBuffer(Literal::create_from_shape_and_untyped_data(
            T::TY, dims, &bytes,
        )?))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer(lit.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let xs = [1.5f32, -2.0, 3.25];
        let mut bytes = Vec::new();
        for v in &xs {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert!(lit.to_vec::<i32>().is_err());
        assert_eq!(lit.array_shape().unwrap().dims(), &[3]);
    }

    #[cfg(not(feature = "real"))]
    #[test]
    fn scalar_and_buffer_roundtrip() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[1i32, 2, 3, 4], &[2, 2], None).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(Literal::scalar(7i32).to_vec::<i32>().unwrap(), vec![7]);
    }

    #[cfg(not(feature = "real"))]
    #[test]
    fn execute_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.compile(&XlaComputation).is_err());
        let e = PjRtLoadedExecutable;
        let args: Vec<&PjRtBuffer> = vec![];
        assert!(e.execute_b::<&PjRtBuffer>(&args).is_err());
    }

    #[test]
    fn npy_header_parsing() {
        let h = "{'descr': '<f4', 'fortran_order': False, 'shape': (2, 3), }";
        assert_eq!(field_str(h, "descr").unwrap(), "<f4");
        assert_eq!(field_shape(h).unwrap(), vec![2, 3]);
        let scalar = "{'descr': '<i4', 'fortran_order': False, 'shape': (), }";
        assert_eq!(field_shape(scalar).unwrap(), Vec::<usize>::new());
    }
}
