//! FFI-backed PJRT surface (feature `real`).
//!
//! Binds the `xla_rs` C shim that the upstream xla-rs crate builds
//! around `libxla_extension`, replacing the offline stub's erroring
//! device path with real compilation and execution. The host-side
//! [`Literal`](super::Literal) (+ npz reading) stays the crate's own —
//! conversions copy bytes across the FFI boundary at upload/download,
//! which is exactly where the engine already expects host copies.
//!
//! Expectations (checked at link time, not compile time):
//! * `XLA_EXTENSION_DIR/lib` contains `libxla_extension` and the
//!   `xla_rs` shim (see `build.rs`);
//! * the shim exports the symbol set below (the stable subset of
//!   xla-rs's `c_lib` used by this repo: client create/free, HLO text
//!   parse, compile, untupled execute, literal upload/download).
//!
//! Status strings returned by the shim are malloc'd C strings; a null
//! return means success.

use std::ffi::{c_char, c_int, CStr, CString};
use std::path::Path;
use std::rc::Rc;

use super::{ElementType, Error, Literal, Result};

// ---------------------------------------------------------------------
// opaque shim handles
// ---------------------------------------------------------------------

#[repr(C)]
struct CClient {
    _opaque: [u8; 0],
}
#[repr(C)]
struct CBuffer {
    _opaque: [u8; 0],
}
#[repr(C)]
struct CExecutable {
    _opaque: [u8; 0],
}
#[repr(C)]
struct CLiteral {
    _opaque: [u8; 0],
}
#[repr(C)]
struct CHloProto {
    _opaque: [u8; 0],
}
#[repr(C)]
struct CComputation {
    _opaque: [u8; 0],
}

/// XLA PrimitiveType values for the two dtypes this repo exchanges.
const PRIMITIVE_S32: c_int = 4;
const PRIMITIVE_F32: c_int = 11;

type CStatus = *mut c_char;

extern "C" {
    fn pjrt_cpu_client_create(out: *mut *mut CClient) -> CStatus;
    fn pjrt_client_free(client: *mut CClient);
    fn pjrt_client_platform_name(client: *mut CClient) -> *mut c_char;

    fn hlo_module_proto_parse_and_return_unverified_module(
        text: *const c_char,
        out: *mut *mut CHloProto,
    ) -> CStatus;
    fn hlo_module_proto_free(proto: *mut CHloProto);
    fn xla_computation_from_hlo_module_proto(proto: *mut CHloProto) -> *mut CComputation;
    fn xla_computation_free(computation: *mut CComputation);

    fn compile(
        client: *mut CClient,
        computation: *const CComputation,
        out: *mut *mut CExecutable,
    ) -> CStatus;
    fn pjrt_loaded_executable_free(exe: *mut CExecutable);
    /// Outputs: null-terminated array (per device) of null-terminated
    /// arrays of buffers; single-device in this repo.
    fn execute_b(
        exe: *mut CExecutable,
        args: *const *mut CBuffer,
        n_args: c_int,
        out: *mut *mut *mut *mut CBuffer,
    ) -> CStatus;

    fn pjrt_buffer_from_host_literal(
        client: *mut CClient,
        device: c_int,
        literal: *const CLiteral,
        out: *mut *mut CBuffer,
    ) -> CStatus;
    fn pjrt_buffer_to_literal_sync(buffer: *mut CBuffer, out: *mut *mut CLiteral) -> CStatus;
    fn pjrt_buffer_free(buffer: *mut CBuffer);

    fn literal_create_from_shape_and_data(
        ty: c_int,
        dims: *const i64,
        n_dims: usize,
        data: *const u8,
        size: usize,
    ) -> *mut CLiteral;
    fn literal_element_type(literal: *const CLiteral) -> c_int;
    fn literal_num_dims(literal: *const CLiteral) -> c_int;
    fn literal_shape_dims(literal: *const CLiteral, out: *mut i64);
    fn literal_size_bytes(literal: *const CLiteral) -> i64;
    fn literal_copy_to(literal: *const CLiteral, dst: *mut u8, size: usize);
    fn literal_free(literal: *mut CLiteral);
}

/// Consume a shim status; `Ok` on null.
fn check(status: CStatus) -> Result<()> {
    if status.is_null() {
        return Ok(());
    }
    let msg = unsafe { CStr::from_ptr(status) }
        .to_string_lossy()
        .into_owned();
    unsafe { libc_free(status.cast()) };
    Err(Error(msg))
}

extern "C" {
    #[link_name = "free"]
    fn libc_free(ptr: *mut std::ffi::c_void);
}

// ---------------------------------------------------------------------
// literal marshalling
// ---------------------------------------------------------------------

/// Guard around a shim-owned literal.
struct OwnedCLiteral(*mut CLiteral);

impl Drop for OwnedCLiteral {
    fn drop(&mut self) {
        unsafe { literal_free(self.0) }
    }
}

fn upload_literal(lit: &Literal) -> Result<OwnedCLiteral> {
    let shape = lit.array_shape()?;
    let dims = shape.dims().to_vec();
    let ty = match shape.element_type() {
        ElementType::F32 => PRIMITIVE_F32,
        ElementType::S32 => PRIMITIVE_S32,
    };
    let bytes = lit.raw_bytes();
    let ptr = unsafe {
        literal_create_from_shape_and_data(ty, dims.as_ptr(), dims.len(), bytes.as_ptr(), bytes.len())
    };
    if ptr.is_null() {
        return Err(Error("literal_create_from_shape_and_data failed".into()));
    }
    Ok(OwnedCLiteral(ptr))
}

fn download_literal(ptr: *mut CLiteral) -> Result<Literal> {
    let guard = OwnedCLiteral(ptr);
    let ty = match unsafe { literal_element_type(guard.0) } {
        PRIMITIVE_F32 => ElementType::F32,
        PRIMITIVE_S32 => ElementType::S32,
        other => return Err(Error(format!("unsupported element type {other}"))),
    };
    let n_dims = unsafe { literal_num_dims(guard.0) } as usize;
    let mut dims = vec![0i64; n_dims];
    if n_dims > 0 {
        unsafe { literal_shape_dims(guard.0, dims.as_mut_ptr()) };
    }
    let size = unsafe { literal_size_bytes(guard.0) } as usize;
    let mut bytes = vec![0u8; size];
    unsafe { literal_copy_to(guard.0, bytes.as_mut_ptr(), size) };
    let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    Literal::create_from_shape_and_untyped_data(ty, &udims, &bytes)
}

// ---------------------------------------------------------------------
// public surface (same shapes as the stub)
// ---------------------------------------------------------------------

pub struct HloModuleProto {
    raw: *mut CHloProto,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("reading {:?}: {e}", path.as_ref())))?;
        let ctext = CString::new(text).map_err(|e| Error(format!("hlo text: {e}")))?;
        let mut raw: *mut CHloProto = std::ptr::null_mut();
        check(unsafe {
            hlo_module_proto_parse_and_return_unverified_module(ctext.as_ptr(), &mut raw)
        })?;
        Ok(HloModuleProto { raw })
    }
}

impl Drop for HloModuleProto {
    fn drop(&mut self) {
        unsafe { hlo_module_proto_free(self.raw) }
    }
}

pub struct XlaComputation {
    raw: *mut CComputation,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation {
            raw: unsafe { xla_computation_from_hlo_module_proto(proto.raw) },
        }
    }
}

impl Drop for XlaComputation {
    fn drop(&mut self) {
        unsafe { xla_computation_free(self.raw) }
    }
}

pub struct PjRtBuffer {
    raw: *mut CBuffer,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        let mut out: *mut CLiteral = std::ptr::null_mut();
        check(unsafe { pjrt_buffer_to_literal_sync(self.raw, &mut out) })?;
        download_literal(out)
    }
}

impl Drop for PjRtBuffer {
    fn drop(&mut self) {
        unsafe { pjrt_buffer_free(self.raw) }
    }
}

pub struct PjRtLoadedExecutable {
    raw: *mut CExecutable,
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        let raw_args: Vec<*mut CBuffer> = args.iter().map(|a| a.borrow().raw).collect();
        let mut out: *mut *mut *mut CBuffer = std::ptr::null_mut();
        check(unsafe {
            execute_b(self.raw, raw_args.as_ptr(), raw_args.len() as c_int, &mut out)
        })?;
        // null-terminated per-device array of null-terminated buffer arrays
        let mut devices = Vec::new();
        let mut d = out;
        unsafe {
            while !(*d).is_null() {
                let mut bufs = Vec::new();
                let mut b = *d;
                while !(*b).is_null() {
                    bufs.push(PjRtBuffer { raw: *b });
                    b = b.add(1);
                }
                libc_free((*d).cast());
                devices.push(bufs);
                d = d.add(1);
            }
            libc_free(out.cast());
        }
        Ok(devices)
    }
}

impl Drop for PjRtLoadedExecutable {
    fn drop(&mut self) {
        unsafe { pjrt_loaded_executable_free(self.raw) }
    }
}

struct ClientHandle(*mut CClient);

impl Drop for ClientHandle {
    fn drop(&mut self) {
        unsafe { pjrt_client_free(self.0) }
    }
}

#[derive(Clone)]
pub struct PjRtClient {
    raw: Rc<ClientHandle>,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        let mut raw: *mut CClient = std::ptr::null_mut();
        check(unsafe { pjrt_cpu_client_create(&mut raw) })?;
        Ok(PjRtClient {
            raw: Rc::new(ClientHandle(raw)),
        })
    }

    pub fn platform_name(&self) -> String {
        let ptr = unsafe { pjrt_client_platform_name(self.raw.0) };
        if ptr.is_null() {
            return "unknown".to_string();
        }
        let name = unsafe { CStr::from_ptr(ptr) }.to_string_lossy().into_owned();
        unsafe { libc_free(ptr.cast()) };
        name
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let mut raw: *mut CExecutable = std::ptr::null_mut();
        check(unsafe { compile(self.raw.0, comp.raw, &mut raw) })?;
        Ok(PjRtLoadedExecutable { raw })
    }

    pub fn buffer_from_host_buffer<T: super::ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let lit = Literal::create_from_shape_and_untyped_data(T::TY, dims, &bytes)?;
        self.buffer_from_host_literal(device, &lit)
    }

    pub fn buffer_from_host_literal(
        &self,
        device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        let clit = upload_literal(lit)?;
        let mut raw: *mut CBuffer = std::ptr::null_mut();
        check(unsafe {
            pjrt_buffer_from_host_literal(
                self.raw.0,
                device.unwrap_or(0) as c_int,
                clit.0,
                &mut raw,
            )
        })?;
        Ok(PjRtBuffer { raw })
    }
}
