//! Stage-2 GA benchmarks: candidate-evaluation rate, full-search wall
//! time per model scale, exact-DP comparison. (In-crate harness; criterion
//! is unavailable offline.)

use lexi_moe::config::model::registry;
use lexi_moe::lexi::evolution::{evolve, exact_dp, EvolutionParams};
use lexi_moe::lexi::SensitivityTable;
use lexi_moe::moe::allocation::Bounds;
use lexi_moe::util::bench::{bench, header};

fn main() {
    header("lexi stage 2 (Alg. 2) — evolutionary search");

    for spec in registry() {
        let table = SensitivityTable::synthetic(
            spec.name,
            spec.n_layers,
            spec.top_k as u32,
            |x| 1.0 + 2.0 * (2.0 * (x - 0.5)).powi(2),
            7,
        );
        let budget = (spec.baseline_budget() as f64 * 0.65) as u32;
        let bounds = Bounds::paper(spec.top_k as u32);
        let params = EvolutionParams::default();
        bench(&format!("ga_400gen/{}", spec.name), || {
            let r = evolve(&table, budget, bounds, &params).unwrap();
            std::hint::black_box(r.best_fitness);
        });
    }

    // fitness-evaluation microbenchmark (the GA inner loop)
    let table = SensitivityTable::synthetic("micro", 40, 8, |x| 1.0 + x, 3);
    let alloc: Vec<u32> = (0..40).map(|i| 1 + (i % 8) as u32).collect();
    bench("fitness_eval_40layers", || {
        std::hint::black_box(table.fitness(&alloc));
    });

    header("exact DP reference solver");
    for spec in registry().into_iter().take(3) {
        let table = SensitivityTable::synthetic(
            spec.name,
            spec.n_layers,
            spec.top_k as u32,
            |x| 1.0 + x,
            9,
        );
        let budget = (spec.baseline_budget() as f64 * 0.65) as u32;
        bench(&format!("dp_exact/{}", spec.name), || {
            std::hint::black_box(exact_dp(&table, budget, Bounds::paper(spec.top_k as u32)));
        });
    }
}
