//! Serving-stack benchmarks over the real PJRT executables: prefill /
//! decode step latency, KV splice, sampler, end-to-end engine loop.
//! Skips gracefully when artifacts are absent (CI without `make
//! artifacts`).

use lexi_moe::config::serving::ServingConfig;
use lexi_moe::engine::{Engine, SamplingParams};
use lexi_moe::eval::RunConfig;
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};
use lexi_moe::util::bench::{bench, header};
use lexi_moe::util::Pcg32;

fn main() {
    let dir = Manifest::default_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping engine bench (no artifacts at {dir:?}): {e}");
            return;
        }
    };
    let rt = Runtime::cpu().expect("pjrt cpu client");

    // Smallest analogue = fastest per-step; also bench the largest.
    for name in ["deepseek-vl2-tiny", "qwen1.5-moe-a2.7b"] {
        if !manifest.models.contains_key(name) {
            continue;
        }
        let model = ModelRuntime::load(&rt, &manifest, name).expect("load model");
        let entry = model.entry.clone();
        let rc = RunConfig::baseline(&entry);
        header(&format!("runtime hot path — {name}"));

        let mut rng = Pcg32::seeded(3);
        let tokens: Vec<i32> = (0..entry.batch * entry.prefill_len)
            .map(|_| 42 + rng.gen_range(128) as i32)
            .collect();
        let pre = model.prefill(&tokens, &rc.k_vec, &rc.gate_bias).unwrap();
        bench("prefill_batch8x96", || {
            std::hint::black_box(model.prefill(&tokens, &rc.k_vec, &rc.gate_bias).unwrap());
        });

        let dtoks = vec![50i32; entry.batch];
        let dpos: Vec<i32> = (0..entry.batch).map(|i| 40 + i as i32).collect();
        bench("decode_step_batch8", || {
            std::hint::black_box(
                model
                    .decode(&pre.kv, &dtoks, &dpos, &rc.k_vec, &rc.gate_bias)
                    .unwrap(),
            );
        });

        bench("moe_layer_probe(stage1 unit)", || {
            let x = vec![0.1f32; entry.profile_tokens * entry.hidden];
            std::hint::black_box(model.moe_layer(0, &x, 1).unwrap());
        });

        // Engine end-to-end: 8 requests through continuous batching.
        bench("engine_8req_e2e", || {
            let scfg = ServingConfig {
                batch: entry.batch,
                max_seq: entry.max_seq,
                prefill_len: entry.prefill_len,
                ..Default::default()
            };
            let mut engine =
                Engine::new(&model, scfg, rc.k_vec.clone(), rc.gate_bias.clone()).unwrap();
            for i in 0..8 {
                engine
                    .submit(
                        tokens[i * 24..(i + 1) * 24].to_vec(),
                        SamplingParams {
                            max_new_tokens: 4,
                            stop_on_eos: false,
                            ..Default::default()
                        },
                    )
                    .unwrap();
            }
            std::hint::black_box(engine.run_until_complete().unwrap());
        });
    }

    header("sampler / host-side microbenches");
    let mut rng = Pcg32::seeded(5);
    let logits: Vec<f32> = (0..256).map(|_| rng.gen_normal() as f32).collect();
    bench("sampler_greedy_v256", || {
        std::hint::black_box(lexi_moe::engine::sampler::argmax(&logits));
    });
    bench("sampler_logprob_v256", || {
        std::hint::black_box(lexi_moe::engine::sampler::log_prob(&logits, 100));
    });
}
