//! Serving front-end benchmarks: scheduler admission throughput, router
//! dispatch, and full cluster replay on a 10k-request synthetic trace.
//! (Perf target: full 10k-request cluster replay well under 1 s — the
//! front-end must never be the bottleneck next to model execution.)
//!
//! The scale section times the PR 8 hot-path flattening on its own:
//! indexed-EDF ops at depth 1e5, incremental vs rebuild-per-instant
//! snapshot assembly at 1000 replicas, and a 100-replica full event
//! loop in both snapshot modes.

use std::rc::Rc;

use lexi_moe::config::server::{PolicyKind, ScenarioKind};
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::server::backend::ReplicaBackend;
use lexi_moe::server::ladder::QualityLadder;
use lexi_moe::server::replica::{Replica, ServiceModel};
use lexi_moe::server::router::Cluster;
use lexi_moe::server::scheduler::{EdfQueue, QueuedRequest};
use lexi_moe::server::telemetry::{SnapshotCache, TelemetryDetail};
use lexi_moe::server::workload::Scenario;
use lexi_moe::util::bench::{bench, header};
use lexi_moe::util::Pcg32;

const N: usize = 10_000;

fn synthetic_queue_load_n(rng: &mut Pcg32, n: usize) -> Vec<QueuedRequest> {
    (0..n as u64)
        .map(|id| QueuedRequest {
            id,
            class: rng.gen_usize(4),
            priority: rng.gen_usize(3) as u8,
            arrival_s: id as f64 * 1e-3,
            deadline_ns: ((id as f64 * 1e-3 + 0.5 + rng.gen_f64()) * 1e9) as u64,
            prompt_len: 64 + rng.gen_usize(512),
            new_tokens: 16 + rng.gen_usize(256),
        })
        .collect()
}

fn main() {
    let mut rng = Pcg32::seeded(0xbe9c);
    let reqs = synthetic_queue_load_n(&mut rng, N);

    header("scheduler: EDF admission on a 10k-request trace");
    bench("edf/push_pop_10k", || {
        let mut q = EdfQueue::new();
        for r in &reqs {
            q.push(r.clone());
        }
        let mut drained = 0usize;
        while q.pop().is_some() {
            drained += 1;
        }
        assert_eq!(drained, N);
        std::hint::black_box(drained);
    });

    header("router: full cluster replay, 10k requests");
    // fast synthetic service so the bench times ONLY the front-end
    let scenarios: Vec<Scenario> = [ScenarioKind::Poisson, ScenarioKind::Bursty]
        .into_iter()
        .map(|k| {
            let mut s = Scenario::from_kind(k, 2000.0);
            s.resolve_slos(|tokens| 1e-7 * tokens as f64 + 1e-5, 2e-4);
            s
        })
        .collect();
    for policy in [PolicyKind::RoundRobin, PolicyKind::Jsq, PolicyKind::PowerOfTwo] {
        for s in &scenarios {
            let trace = s.generate(N, 1);
            bench(&format!("cluster/{}/{}/10k", policy.label(), s.name), || {
                let ladder = QualityLadder::fixed(
                    "base",
                    Allocation::uniform(4, 2),
                    ServiceModel::synthetic("base", 1e-7, 1e-4, 16),
                );
                let mut c = Cluster::new(8, 16, policy, ladder, None, 4096, 4, 0.0, 0);
                let res = c.run(s, &trace);
                assert!(res.completed.len() + res.rejected_by_class.iter().sum::<u64>() as usize == N);
                std::hint::black_box(res.completed.len());
            });
        }
    }

    header("scheduler: indexed EDF at depth 100k");
    let deep = synthetic_queue_load_n(&mut rng, 100_000);
    bench("edf/push_pop_100k", || {
        let mut q = EdfQueue::new();
        for r in &deep {
            q.push(r.clone());
        }
        let mut drained = 0usize;
        while q.pop().is_some() {
            drained += 1;
        }
        assert_eq!(drained, deep.len());
        std::hint::black_box(drained);
    });
    // alternating dispatch pops and worst-slack (steal-donor) pops: the
    // pre-indexed pop_min_deadline drained and rebuilt the whole heap
    bench("edf/steal_drain_100k", || {
        let mut q = EdfQueue::new();
        for r in &deep {
            q.push(r.clone());
        }
        let mut drained = 0usize;
        loop {
            let a = q.pop().is_some();
            let b = q.pop_min_deadline().is_some();
            drained += a as usize + b as usize;
            if !a && !b {
                break;
            }
        }
        assert_eq!(drained, deep.len());
        std::hint::black_box(drained);
    });

    header("telemetry: snapshot assembly, 1000 replicas");
    let ladder = Rc::new(QualityLadder::fixed(
        "base",
        Allocation::uniform(4, 2),
        ServiceModel::synthetic("base", 1e-7, 1e-4, 8),
    ));
    let backends: Vec<Box<dyn ReplicaBackend>> = (0..1000)
        .map(|i| Box::new(Replica::new(i, 8, Rc::clone(&ladder))) as Box<dyn ReplicaBackend>)
        .collect();
    for detail in [TelemetryDetail::Load, TelemetryDetail::Full] {
        let tag = if detail == TelemetryDetail::Load { "load" } else { "full" };
        let mut now = 0.0;
        let mut cache = SnapshotCache::new(backends.len(), detail);
        cache.set_rebuild(true);
        bench(&format!("snapshot/rebuild_{tag}_1000"), || {
            now += 1e-3;
            cache.refresh(&backends, now);
            std::hint::black_box(cache.snap().replicas.len());
        });
        let mut cache = SnapshotCache::new(backends.len(), detail);
        bench(&format!("snapshot/incremental_{tag}_1000"), || {
            now += 1e-3;
            cache.refresh(&backends, now);
            std::hint::black_box(cache.snap().replicas.len());
        });
    }

    header("router: full event loop, 100 replicas x 20k requests");
    let svc = ServiceModel::synthetic("base", 1e-7, 1e-4, 8);
    // capacity sized from the catalog mixture so the diurnal peak
    // actually saturates the 100-replica cluster
    let probe = Scenario::from_kind(ScenarioKind::Diurnal, 1.0);
    let capacity = 100.0 * svc.capacity_rps(probe.mean_prompt_tokens(), probe.mean_gen_tokens());
    let mut s = Scenario::from_kind(ScenarioKind::Diurnal, capacity);
    s.resolve_slos(|tokens| 1e-7 * tokens as f64 + 1e-5, 2e-4);
    let trace = s.generate(20_000, 1);
    for (tag, rebuild) in [("incremental", false), ("rebuild", true)] {
        bench(&format!("cluster/jsq/diurnal/100rx20k/{tag}"), || {
            let ladder = QualityLadder::fixed(
                "base",
                Allocation::uniform(4, 2),
                ServiceModel::synthetic("base", 1e-7, 1e-4, 8),
            );
            let mut c = Cluster::new(100, 8, PolicyKind::Jsq, ladder, None, 6400, 4, 0.0, 0);
            if rebuild {
                c = c.with_snapshot_rebuild();
            }
            let res = c.run(&s, &trace);
            assert_eq!(
                res.completed.len() + res.rejected_by_class.iter().sum::<u64>() as usize,
                20_000
            );
            std::hint::black_box(res.completed.len());
        });
    }
}
