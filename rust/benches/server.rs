//! Serving front-end benchmarks: scheduler admission throughput, router
//! dispatch, and full cluster replay on a 10k-request synthetic trace.
//! (Perf target: full 10k-request cluster replay well under 1 s — the
//! front-end must never be the bottleneck next to model execution.)

use lexi_moe::config::server::{PolicyKind, ScenarioKind};
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::server::ladder::QualityLadder;
use lexi_moe::server::replica::ServiceModel;
use lexi_moe::server::router::Cluster;
use lexi_moe::server::scheduler::{EdfQueue, QueuedRequest};
use lexi_moe::server::workload::Scenario;
use lexi_moe::util::bench::{bench, header};
use lexi_moe::util::Pcg32;

const N: usize = 10_000;

fn synthetic_queue_load(rng: &mut Pcg32) -> Vec<QueuedRequest> {
    (0..N as u64)
        .map(|id| QueuedRequest {
            id,
            class: rng.gen_usize(4),
            priority: rng.gen_usize(3) as u8,
            arrival_s: id as f64 * 1e-3,
            deadline_ns: ((id as f64 * 1e-3 + 0.5 + rng.gen_f64()) * 1e9) as u64,
            prompt_len: 64 + rng.gen_usize(512),
            new_tokens: 16 + rng.gen_usize(256),
        })
        .collect()
}

fn main() {
    let mut rng = Pcg32::seeded(0xbe9c);
    let reqs = synthetic_queue_load(&mut rng);

    header("scheduler: EDF admission on a 10k-request trace");
    bench("edf/push_pop_10k", || {
        let mut q = EdfQueue::new();
        for r in &reqs {
            q.push(r.clone());
        }
        let mut drained = 0usize;
        while q.pop().is_some() {
            drained += 1;
        }
        assert_eq!(drained, N);
        std::hint::black_box(drained);
    });

    header("router: full cluster replay, 10k requests");
    // fast synthetic service so the bench times ONLY the front-end
    let scenarios: Vec<Scenario> = [ScenarioKind::Poisson, ScenarioKind::Bursty]
        .into_iter()
        .map(|k| {
            let mut s = Scenario::from_kind(k, 2000.0);
            s.resolve_slos(|tokens| 1e-7 * tokens as f64 + 1e-5, 2e-4);
            s
        })
        .collect();
    for policy in [PolicyKind::RoundRobin, PolicyKind::Jsq, PolicyKind::PowerOfTwo] {
        for s in &scenarios {
            let trace = s.generate(N, 1);
            bench(&format!("cluster/{}/{}/10k", policy.label(), s.name), || {
                let ladder = QualityLadder::fixed(
                    "base",
                    Allocation::uniform(4, 2),
                    ServiceModel::synthetic("base", 1e-7, 1e-4, 16),
                );
                let mut c = Cluster::new(8, 16, policy, ladder, None, 4096, 4, 0.0, 0);
                let res = c.run(s, &trace);
                assert!(res.completed.len() + res.rejected_by_class.iter().sum::<u64>() as usize == N);
                std::hint::black_box(res.completed.len());
            });
        }
    }
}
