//! Figure-harness benchmarks: wall time to regenerate each deliverable
//! (table 1, Fig. 2 full; Stage-1-dependent figures benched at fast
//! settings when artifacts exist).

use lexi_moe::config::experiment::ExperimentConfig;
use lexi_moe::figures;
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};
use lexi_moe::util::bench::{bench, bench_with_budget, header};

fn main() {
    let out = std::env::temp_dir().join("lexi_bench_figs");
    header("figure regeneration (analytic figures)");
    bench("table1", || {
        std::hint::black_box(figures::table1::run(&out).unwrap());
    });
    let cfg = ExperimentConfig::default();
    bench("fig2_full_6models", || {
        std::hint::black_box(figures::fig2::run(&out, &cfg).unwrap());
    });

    // Stage-1 figure at fast settings (needs artifacts).
    let dir = Manifest::default_dir();
    if let Ok(manifest) = Manifest::load(&dir) {
        let rt = Runtime::cpu().unwrap();
        header("stage-1 profiling (fast settings, smallest model)");
        let fast = ExperimentConfig::fast();
        let model = ModelRuntime::load(&rt, &manifest, "deepseek-vl2-tiny").unwrap();
        bench_with_budget(
            "stage1_profile_vl2_fast",
            std::time::Duration::from_secs(10),
            &mut || {
                std::hint::black_box(
                    lexi_moe::lexi::sensitivity::profile_model(&model, &fast, None).unwrap(),
                );
            },
        );
    } else {
        eprintln!("(artifacts missing — skipping stage-1 figure bench)");
    }
}
