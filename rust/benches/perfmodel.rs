//! H100 performance-model benchmarks: per-config throughput evaluation
//! cost and the full Fig. 2 sweep (perf target: full sweep < 1 s/model).

use lexi_moe::config::experiment::ExperimentConfig;
use lexi_moe::config::model::{registry, spec};
use lexi_moe::figures::fig2;
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::moe::transform::Transform;
use lexi_moe::perfmodel::PerfModel;
use lexi_moe::util::bench::{bench, header};

fn main() {
    header("perfmodel: single-config throughput evaluations");
    for name in ["mixtral-8x7b", "olmoe-1b-7b", "qwen1.5-moe-a2.7b"] {
        let pm = PerfModel::new(spec(name).unwrap(), 0);
        bench(&format!("throughput/base/{name}"), || {
            std::hint::black_box(pm.throughput(&Transform::Baseline, 16, 1024, 512));
        });
        bench(&format!("throughput/inter50/{name}"), || {
            std::hint::black_box(pm.throughput(
                &Transform::InterPrune { frac: 0.5 },
                16,
                1024,
                512,
            ));
        });
        let m = spec(name).unwrap();
        let lexi = Transform::Lexi {
            allocation: Allocation::uniform(m.n_layers, 2),
        };
        bench(&format!("throughput/lexi/{name}"), || {
            std::hint::black_box(pm.throughput(&lexi, 16, 1024, 512));
        });
    }

    header("perfmodel: full Fig. 2 sweep per model");
    let cfg = ExperimentConfig::default();
    for m in registry() {
        bench(&format!("fig2_sweep/{}", m.name), || {
            std::hint::black_box(fig2::sweep_model(&m, &cfg).unwrap());
        });
    }
}
