//! Quickstart: load an analogue model through the PJRT runtime, submit a
//! prompt to the serving engine, and print the generated tokens.
//!
//!     cargo run --release --example quickstart -- [model]
//!
//! Requires `make artifacts` (trains the tiny analogues once).

use anyhow::Result;
use lexi_moe::config::serving::ServingConfig;
use lexi_moe::engine::{Engine, SamplingParams, Tokenizer};
use lexi_moe::eval::{EvalSuite, RunConfig};
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};

fn main() -> Result<()> {
    let model_name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "mixtral-8x7b".to_string());

    // 1. Load the AOT artifacts (HLO text + trained weights).
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(Manifest::default_dir())?;
    let model = ModelRuntime::load(&rt, &manifest, &model_name)?;
    let entry = model.entry.clone();
    println!(
        "loaded {} ({} layers, {} experts, top-{}) on {}",
        entry.name, entry.n_layers, entry.n_experts, entry.top_k,
        rt.platform()
    );

    // 2. Start a serving engine at the baseline configuration.
    let scfg = ServingConfig {
        batch: entry.batch,
        max_seq: entry.max_seq,
        prefill_len: entry.prefill_len,
        ..Default::default()
    };
    let rc = RunConfig::baseline(&entry);
    let mut engine = Engine::new(&model, scfg, rc.k_vec, rc.gate_bias)?;

    // 3. Submit a prompt from the held-out corpus and generate.
    let suite = EvalSuite::load(&manifest)?;
    let prompt = suite.ppl_seqs("c4")?.row(0)[..32].to_vec();
    let tok = Tokenizer::new(manifest.vocab.clone());
    println!("prompt:    {}", tok.render_seq(&prompt));
    engine.submit(
        prompt,
        SamplingParams {
            max_new_tokens: 12,
            stop_on_eos: false,
            ..Default::default()
        },
    )?;
    let outs = engine.run_until_complete()?;
    println!("generated: {}", tok.render_seq(&outs[0].tokens));
    println!("\n{}", engine.metrics.summary());
    Ok(())
}
