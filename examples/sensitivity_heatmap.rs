//! ASCII rendering of the Fig. 3 sensitivity heatmap for one model:
//! per-layer normalized top-k perturbation loss (Alg. 1).
//!
//!     cargo run --release --example sensitivity_heatmap -- [model] [iters]

use anyhow::Result;
use lexi_moe::config::experiment::ExperimentConfig;
use lexi_moe::lexi::pipeline::{stage1, table_path};
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};

const SHADES: [char; 10] = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];

fn main() -> Result<()> {
    let model_name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "olmoe-1b-7b".to_string());
    let mut cfg = ExperimentConfig::default();
    if let Some(it) = std::env::args().nth(2) {
        cfg.sensitivity_iters = it.parse()?;
    }

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(Manifest::default_dir())?;
    let model = ModelRuntime::load(&rt, &manifest, &model_name)?;
    let table = stage1(
        &model,
        &cfg,
        Some(&table_path(&manifest.root, &model_name)),
        false,
    )?;

    println!(
        "top-k sensitivity heatmap: {} (rows = k, cols = layer; darker = larger Δ)",
        table.model
    );
    // global normalization so depth structure is visible
    let max = table
        .loss
        .iter()
        .flatten()
        .cloned()
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    for k in 1..=table.k_base {
        let mut row = String::new();
        for layer in 0..table.n_layers() {
            let v = table.d(layer, k) / max;
            let idx = ((v * (SHADES.len() - 1) as f64).round() as usize).min(SHADES.len() - 1);
            row.push(SHADES[idx]);
        }
        println!("k={k:<2} |{row}|");
    }
    println!(
        "      {}",
        (0..table.n_layers())
            .map(|l| if l % 10 == 0 { '|' } else { ' ' })
            .collect::<String>()
    );
    println!("layer 0..{}", table.n_layers() - 1);

    // depth profile summary (which end of the model is sensitive?)
    let l = table.n_layers();
    let front: f64 = table.loss[..l / 3].iter().map(|r| r[0]).sum::<f64>() / (l / 3) as f64;
    let back: f64 = table.loss[l - l / 3..].iter().map(|r| r[0]).sum::<f64>() / (l / 3) as f64;
    let mid: f64 = table.loss[l / 3..l - l / 3]
        .iter()
        .map(|r| r[0])
        .sum::<f64>()
        / (l - 2 * (l / 3)) as f64;
    println!("\nΔ(k=1) depth profile: front {front:.2}  mid {mid:.2}  back {back:.2}");
    Ok(())
}
