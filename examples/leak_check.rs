//! Memory-stability check for the execute_b runtime path (regression
//! guard for the upstream execute() input-buffer leak — see
//! runtime/executable.rs). Run: cargo run --release --example leak_check
use lexi_moe::eval::RunConfig;
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    s.lines()
        .find(|l| l.starts_with("VmRSS"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse::<f64>().ok())
        .map(|kb| kb / 1024.0)
        .unwrap_or(0.0)
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let m = Manifest::load(Manifest::default_dir())?;
    let model = ModelRuntime::load(&rt, &m, "deepseek-vl2-tiny")?;
    let e = model.entry.clone();
    let rc = RunConfig::baseline(&e);
    let tokens: Vec<i32> = (0..e.batch * e.prefill_len)
        .map(|i| 42 + (i as i32 % 128))
        .collect();
    let start = rss_mb();
    println!("start rss {start:.0} MB");
    for i in 0..60 {
        let out = model.prefill(&tokens, &rc.k_vec, &rc.gate_bias)?;
        drop(out);
        if i % 20 == 19 {
            println!("prefill iter {i}: rss {:.0} MB", rss_mb());
        }
    }
    let pre = model.prefill(&tokens, &rc.k_vec, &rc.gate_bias)?;
    let toks = vec![50i32; e.batch];
    let pos = vec![40i32; e.batch];
    let mut kv = pre.kv;
    for i in 0..60 {
        let d = model.decode(&kv, &toks, &pos, &rc.k_vec, &rc.gate_bias)?;
        kv = d.kv;
        if i % 20 == 19 {
            println!("decode iter {i}: rss {:.0} MB", rss_mb());
        }
    }
    let end = rss_mb();
    println!("end rss {end:.0} MB (grew {:.0} MB over 120 forwards)", end - start);
    if end - start > 300.0 {
        anyhow::bail!("leak detected: {:.0} MB growth", end - start);
    }
    println!("leak check OK");
    Ok(())
}
