//! The full LExI pipeline on one model, end to end:
//!
//!   Stage 1  — data-free Monte-Carlo sensitivity profiling (Alg. 1)
//!   Stage 2  — evolutionary allocation search per budget (Alg. 2)
//!   Validate — measured accuracy (probe suite) + modeled H100 throughput
//!              for baseline vs LExI vs uniform-k ablation
//!
//!     cargo run --release --example lexi_optimize -- [model] [iters]

use anyhow::Result;
use lexi_moe::config::experiment::ExperimentConfig;
use lexi_moe::config::model::spec;
use lexi_moe::eval::{multiple_choice as mc, EvalSuite, RunConfig};
use lexi_moe::lexi::pipeline::{stage1, stage2, table_path};
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::moe::transform::Transform;
use lexi_moe::perfmodel::PerfModel;
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};

fn main() -> Result<()> {
    let model_name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "qwen1.5-moe-a2.7b".to_string());
    let mut cfg = ExperimentConfig::default();
    if let Some(it) = std::env::args().nth(2) {
        cfg.sensitivity_iters = it.parse()?;
    }

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(Manifest::default_dir())?;
    let model = ModelRuntime::load(&rt, &manifest, &model_name)?;
    let mspec = spec(&model_name)?;
    let entry = model.entry.clone();

    // Stage 1 (cached in artifacts/<model>/sensitivity.json).
    let t0 = std::time::Instant::now();
    let table = stage1(
        &model,
        &cfg,
        Some(&table_path(&manifest.root, &model_name)),
        false,
    )?;
    println!(
        "stage 1: {} layers x k<={} in {:.1}s ({} iters/layer)",
        table.n_layers(),
        table.k_base,
        t0.elapsed().as_secs_f64(),
        table.iters
    );

    // Stage 2 per budget + validation.
    let suite = EvalSuite::load(&manifest)?;
    let pm = PerfModel::new(mspec.clone(), cfg.seed);
    println!(
        "\n{:<24} {:>8} {:>13} {:>10}",
        "config", "budget", "tok/s (H100)", "probe acc"
    );

    let eval_cfg = |rc: &RunConfig| -> Result<f64> {
        let scores = mc::task_suite(&model, &suite, &mc::lmeval_tasks(&suite), rc)?;
        Ok(mc::mean_accuracy(&scores))
    };

    let base_rc = RunConfig::baseline(&entry);
    let base_t = pm.throughput(&Transform::Baseline, 16, 1024, 512);
    println!(
        "{:<24} {:>8} {:>13.1} {:>10.3}",
        "baseline",
        mspec.baseline_budget(),
        base_t.throughput_tok_s,
        eval_cfg(&base_rc)?
    );

    for budget in mspec.budget_sweep() {
        let t1 = std::time::Instant::now();
        let res = stage2(&table, budget as u32, &cfg)?;
        let lexi = Transform::Lexi {
            allocation: res.best.clone(),
        };
        let rc = RunConfig::for_transform(&entry, &lexi, None)?;
        let tput = pm.throughput(&lexi, 16, 1024, 512);
        println!(
            "{:<24} {:>8} {:>13.1} {:>10.3}   (search {:.2}s, {} evals)",
            format!("lexi B={budget}"),
            budget,
            tput.throughput_tok_s,
            eval_cfg(&rc)?,
            t1.elapsed().as_secs_f64(),
            res.evaluations
        );
        println!("  allocation: {}", res.best);

        // ablation: uniform allocation at (roughly) the same budget
        let uni_k = ((budget as f64 / mspec.n_layers as f64).round().max(1.0) as u32)
            .min(mspec.top_k as u32);
        let uni = Transform::Lexi {
            allocation: Allocation::uniform(mspec.n_layers, uni_k),
        };
        let urc = RunConfig::for_transform(&entry, &uni, None)?;
        let utput = pm.throughput(&uni, 16, 1024, 512);
        println!(
            "{:<24} {:>8} {:>13.1} {:>10.3}",
            format!("uniform k={uni_k}"),
            uni_k as usize * mspec.n_layers,
            utput.throughput_tok_s,
            eval_cfg(&urc)?
        );
    }
    Ok(())
}
