//! SERVING BENCHMARK DRIVER (DESIGN.md §7, now over `server::`).
//!
//! Replays every workload scenario (Poisson, bursty MMPP, diurnal ramp,
//! closed loop, flash crowd) through the multi-replica front-end and
//! reports, per transform:
//!
//!   * baseline      (uniform pretrained top-k, fixed)
//!   * lexi-fixed    (static Stage-2 allocation at the mid-ladder budget)
//!   * lexi-ladder   (adaptive quality ladder: budget follows load)
//!   * inter-prune   (50% experts removed, NAEE-style)
//!
//! With the default `sim` backend, replicas run in virtual time against
//! perf-model-calibrated service models, so the sweep needs no artifacts
//! and is bit-reproducible from the seed; the `engine` backend drives
//! real `engine::Engine` replicas through the same front door. When a
//! measured Stage-1 sensitivity table is cached in the artifacts dir it
//! is used for the ladder's allocations; otherwise a synthetic depth
//! profile stands in. Results land in
//! results/bench_serve_<model>_<scenario>.{csv,json}.
//!
//!     cargo run --release --example serve_benchmark -- [model] [n_requests] [sim|engine]

use anyhow::Result;
use lexi_moe::config::model::spec;
use lexi_moe::config::server::{
    BackendKind, LadderScope, PolicyKind, PressureMode, ScenarioKind, ServerConfig,
};
use lexi_moe::runtime::Manifest;
use lexi_moe::server::{self, report};

fn main() -> Result<()> {
    let model_name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "mixtral-8x7b".to_string());
    let n_requests: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(512);
    let backend = match std::env::args().nth(3) {
        Some(b) => BackendKind::parse(&b)?,
        None => BackendKind::Sim,
    };

    let mspec = spec(&model_name)?;
    let cfg_base = ServerConfig {
        n_requests,
        backend,
        ..Default::default()
    };
    let artifacts = Manifest::default_dir();
    let artifacts_opt = artifacts.exists().then_some(artifacts.as_path());
    let out = std::path::PathBuf::from("results");

    println!(
        "=== serve_benchmark: {model_name}, {} replicas x {} slots, policy {}, backend {}, \
         {n_requests} requests/scenario ===\n",
        cfg_base.replicas,
        cfg_base.slots_per_replica,
        cfg_base.policy.label(),
        cfg_base.backend.label()
    );
    report::print_header();
    for kind in ScenarioKind::all() {
        let cfg = ServerConfig {
            scenario: kind,
            ..cfg_base.clone()
        };
        let reports = server::bench_serve(&mspec, &cfg, artifacts_opt, &out)?;
        println!("-- {kind:?} --");
        report::print_comparison(&reports);
    }
    // Second pass: the telemetry-driven control plane (class-aware
    // routing + EDF-slack ladder + work stealing) on the overload
    // scenarios. Separate out dir: the default sweep's artifacts above
    // stay bit-comparable across releases.
    let cp_out = out.join("control_plane");
    println!("\n=== control plane: classaware routing, slack ladder, stealing ===\n");
    report::print_header();
    for kind in [ScenarioKind::Bursty, ScenarioKind::FlashCrowd] {
        let cfg = ServerConfig {
            scenario: kind,
            policy: PolicyKind::ClassAware,
            pressure: PressureMode::Slack,
            ladder_scope: LadderScope::Cluster,
            steal_bound: 1,
            ..cfg_base.clone()
        };
        let reports = server::bench_serve(&mspec, &cfg, artifacts_opt, &cp_out)?;
        println!("-- {kind:?} --");
        report::print_comparison(&reports);
    }
    println!(
        "reports in {}/ (+ control_plane/); service times are the analytical H100 model \
         (DESIGN.md §3) —\n\
         run `lexi serve` against compiled artifacts for the measured single-engine stack.",
        out.display()
    );
    Ok(())
}
