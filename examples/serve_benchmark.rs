//! END-TO-END VALIDATION DRIVER (DESIGN.md §7).
//!
//! Loads a trained analogue through the full stack (manifest -> HLO
//! compile -> weight upload -> continuous-batching engine), replays a
//! Poisson request trace, and reports latency/throughput for:
//!
//!   * baseline        (uniform pretrained top-k)
//!   * LExI            (Stage-1 + Stage-2 allocation at ~65% budget)
//!   * inter-pruning   (50% experts removed, NAEE-style)
//!
//! Measured CPU numbers prove all layers compose; the H100 *modeled*
//! throughput column shows the paper-scale effect of each transform.
//! Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example serve_benchmark -- [model] [n_requests]

use anyhow::Result;
use lexi_moe::config::experiment::ExperimentConfig;
use lexi_moe::config::model::spec;
use lexi_moe::config::serving::ServingConfig;
use lexi_moe::engine::{Engine, MetricsSummary, SamplingParams};
use lexi_moe::eval::{EvalSuite, RunConfig};
use lexi_moe::lexi::pipeline::{stage1, stage2, table_path};
use lexi_moe::moe::transform::Transform;
use lexi_moe::perfmodel::PerfModel;
use lexi_moe::runtime::weights::CalibStats;
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};
use lexi_moe::util::Pcg32;

fn run_trace(
    model: &ModelRuntime,
    rc: &RunConfig,
    n_requests: usize,
    seed: u64,
    suite: &EvalSuite,
) -> Result<MetricsSummary> {
    let entry = &model.entry;
    let scfg = ServingConfig {
        batch: entry.batch,
        max_seq: entry.max_seq,
        prefill_len: entry.prefill_len,
        ..Default::default()
    };
    let mut engine = Engine::new(model, scfg, rc.k_vec.clone(), rc.gate_bias.clone())?;
    let mut rng = Pcg32::seeded(seed);
    let seqs = suite.ppl_seqs("c4")?;
    // Poisson-ish arrivals: enqueue in bursts whose sizes follow the
    // inter-arrival distribution (the single-threaded engine drains
    // between bursts, so burst structure is what matters).
    let mut submitted = 0usize;
    engine.metrics.start();
    while submitted < n_requests {
        let burst = 1 + (rng.gen_exp(0.6) as usize).min(5);
        for _ in 0..burst.min(n_requests - submitted) {
            let row = seqs.row(submitted % seqs.n_rows());
            let plen = 24 + rng.gen_usize(40);
            engine.submit(
                row[..plen.min(row.len())].to_vec(),
                SamplingParams {
                    max_new_tokens: 8 + rng.gen_usize(8),
                    stop_on_eos: false,
                    ..Default::default()
                },
            )?;
            submitted += 1;
        }
        // drain a few steps between bursts (interleaves prefill + decode)
        for _ in 0..4 {
            engine.step()?;
        }
    }
    while !engine.idle() {
        engine.step()?;
    }
    engine.metrics.finish();
    Ok(engine.metrics.summary())
}

fn main() -> Result<()> {
    let model_name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "mixtral-8x7b".to_string());
    let n_requests: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(24);

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(Manifest::default_dir())?;
    let suite = EvalSuite::load(&manifest)?;
    let mspec = spec(&model_name)?;
    let cfg = ExperimentConfig::default();

    println!("=== serve_benchmark: {model_name}, {n_requests} requests ===\n");

    // Build the three configurations.
    let model = ModelRuntime::load(&rt, &manifest, &model_name)?;
    let entry = model.entry.clone();
    let calib = CalibStats::load_npz(
        manifest.model_dir(&model_name).join(&entry.files.calib),
        entry.n_layers,
        entry.n_experts,
    )?;
    let table = stage1(
        &model,
        &cfg,
        Some(&table_path(&manifest.root, &model_name)),
        false,
    )?;
    let budget = (mspec.baseline_budget() as f64 * 0.65).round() as u32;
    let lexi_alloc = stage2(&table, budget.max(mspec.n_layers as u32), &cfg)?.best;

    let configs: Vec<(String, Transform)> = vec![
        ("baseline".into(), Transform::Baseline),
        (
            format!("lexi B={}", lexi_alloc.budget()),
            Transform::Lexi {
                allocation: lexi_alloc,
            },
        ),
        ("inter-prune 50%".into(), Transform::InterPrune { frac: 0.5 }),
    ];

    let pm = PerfModel::new(mspec.clone(), cfg.seed).with_calibration(&calib.sel_freq);
    println!(
        "{:<18} {:>12} {:>12} {:>12} {:>12} {:>14}",
        "config", "tok/s (CPU)", "p50 e2e ms", "p99 e2e ms", "slot util", "tok/s (H100*)"
    );
    for (label, t) in &configs {
        let rc = RunConfig::for_transform(&entry, t, Some(&calib))?;
        let s = run_trace(&model, &rc, n_requests, 42, &suite)?;
        let modeled = pm.throughput(t, 16, 1024, 512).throughput_tok_s;
        println!(
            "{:<18} {:>12.1} {:>12.1} {:>12.1} {:>11.0}% {:>14.1}",
            label,
            s.total_tok_s,
            s.e2e_p50_s * 1e3,
            s.e2e_p99_s * 1e3,
            s.slot_utilization * 100.0,
            modeled
        );
    }
    println!("\n* analytical H100 model at paper scale (DESIGN.md §3); CPU numbers are\n  the real measured three-layer stack on this machine's single core.");
    Ok(())
}
